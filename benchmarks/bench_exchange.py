"""Boundary-exchange policy sweep (DESIGN.md §10): modeled latency vs
measured quality drift per exchange mode (sync / stale_async / predictive).

Latency: the ``"simulate"`` pipeline backend replays the schedule IR for an
SDXL-scale denoiser (sdxl-dit: DiT-XL/2-class staged K/V, ~8 MB per token
row per boundary) on a 2-tier heterogeneous cluster — two nodes at
effective speeds [1.0, 0.5] linked by commodity 10 GbE (1.25 GB/s), the
cross-node heterogeneous deployment STADI targets. In that regime the
interval boundary is communication-bound (the staged K/V broadcast exceeds
the interval's compute), so skipping the exchange on E-1 of every E
boundaries is a direct makespan win; the acceptance bar is >= 20% modeled
reduction for stale_async vs sync.

Quality: the emulated engine runs real numerics on tiny-dit (reduced) per
mode and reports PSNR vs ``run_origin``. Untrained DiT params are
adaLN-zero (eps would be buffer-independent), so the quality sweep
de-degenerates them with small deterministic modulation weights — remote
K/V then genuinely feeds attention and staleness genuinely drifts. The
contract: every degraded mode stays within 1 dB of sync's PSNR.

Writes results/exchange.json (CI artifact; ``--smoke`` runs 2 modes).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import CostModel
from repro.models.diffusion import dit

# 2-tier heterogeneous cluster profile: fast node + half-speed node over
# commodity 10 GbE; per-step costs in the DiT-XL/2 class (one full-image
# denoiser eval ~ 40 ms on the fast node)
OCCUPANCIES = [0.0, 0.5]
CLUSTER_CM = CostModel(t_fixed=5e-3, t_row=5.5e-4,
                       link_bw=1.25e9, link_latency=50e-6)
M_BASE_LAT, M_WARMUP_LAT = 100, 4
REFRESH = 2                       # one full refresh every 2 boundaries


def nondegenerate_params(cfg, seed: int = 7):
    """Untrained tiny-dit is adaLN-zero (eps ignores attention, so every
    exchange mode would be trivially bitwise-identical); de-degenerate it
    so staleness genuinely drifts (`dit.nondegenerate_params`)."""
    return dit.nondegenerate_params(dit.init_params(jax.random.PRNGKey(0),
                                                    cfg), seed)


def modeled_latency(modes):
    """Modeled makespan per exchange mode on the 2-tier cluster profile."""
    cfg = get_config("sdxl-dit")
    out = {}
    base = StadiConfig.from_occupancies(
        OCCUPANCIES, m_base=M_BASE_LAT, m_warmup=M_WARMUP_LAT,
        backend="simulate", cost_model=CLUSTER_CM,
        granularity=2)                      # paper's P_total=32 slab constraint
    for mode in modes:
        config = dataclasses.replace(base, exchange=mode,
                                     exchange_refresh=REFRESH)
        res = StadiPipeline(cfg, None, None, config).generate()
        kinds = [e.exchange for e in res.trace.events if not e.synchronous]
        out[mode] = {"latency_s": res.latency_s,
                     "boundaries_full": kinds.count("full"),
                     "boundaries_degraded": len(kinds) - kinds.count("full")}
    for mode in modes:
        out[mode]["reduction_vs_sync_pct"] = (
            (1.0 - out[mode]["latency_s"] / out["sync"]["latency_s"]) * 100.0)
    return out


def quality(modes, m_base: int, m_warmup: int):
    """PSNR vs run_origin per exchange mode, real numerics (emulated)."""
    cfg = get_config("tiny-dit").reduced()
    params = nondegenerate_params(cfg)
    sched = sampler_lib.linear_schedule(T=100)
    B = 2
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (B, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.arange(B, dtype=jnp.int32) % cfg.n_classes
    origin = np.asarray(pp.run_origin(params, cfg, sched, x_T, cond, m_base))
    out = {}
    for mode in modes:
        config = StadiConfig.from_occupancies(
            OCCUPANCIES, m_base=m_base, m_warmup=m_warmup,
            exchange=mode, exchange_refresh=REFRESH)
        img = np.asarray(StadiPipeline(cfg, params, sched,
                                       config).generate(x_T, cond).image)
        out[mode] = {"psnr_vs_origin_db": common.psnr(img, origin)}
    for mode in modes:
        out[mode]["psnr_drift_vs_sync_db"] = (
            out["sync"]["psnr_vs_origin_db"] - out[mode]["psnr_vs_origin_db"])
    return out


def run(emit: bool = True):
    smoke = common.smoke()
    modes = ["sync", "stale_async"] if smoke else \
        ["sync", "stale_async", "predictive"]
    lat = modeled_latency(modes)
    qual = quality(modes, m_base=8 if smoke else 16,
                   m_warmup=2 if smoke else 4)
    for mode in modes:
        if emit:
            common.emit(f"exchange/{mode}/latency",
                        lat[mode]["latency_s"] * 1e6,
                        f"reduction={lat[mode]['reduction_vs_sync_pct']:.1f}%")
            common.emit(f"exchange/{mode}/psnr",
                        qual[mode]["psnr_vs_origin_db"],
                        f"drift={qual[mode]['psnr_drift_vs_sync_db']:.2f}dB")
    payload = {
        "cluster": {"occupancies": OCCUPANCIES,
                    "cost_model": dataclasses.asdict(CLUSTER_CM),
                    "refresh_every": REFRESH},
        "latency_arch": "sdxl-dit", "quality_arch": "tiny-dit(reduced)",
        "latency": lat, "quality": qual,
    }
    common.write_json("exchange.json", payload)
    return payload


def main():
    res = run()
    lat, qual = res["latency"], res["quality"]
    red = lat["stale_async"]["reduction_vs_sync_pct"]
    print(f"# stale_async modeled reduction vs sync: {red:.1f}% "
          f"(acceptance: >= 20%)")
    for mode, q in qual.items():
        print(f"# {mode}: PSNR {q['psnr_vs_origin_db']:.2f} dB "
              f"(drift {q['psnr_drift_vs_sync_db']:+.2f} dB vs sync)")
    assert red >= 20.0, (red, lat)
    for mode, q in qual.items():
        assert q["psnr_drift_vs_sync_db"] <= 1.0, (mode, qual)


if __name__ == "__main__":
    main()
