"""Displaced patch-pipeline sweep (DESIGN.md §11): modeled latency of depth
pipelining vs pure patch parallelism on a 2-tier heterogeneous cluster, plus
measured displaced-activation quality drift.

Latency: the ``"simulate"`` backend replays the schedule IR for the
depth-heavy sdxl-dit (28 DiT-XL/2-class blocks) on two nodes at effective
speeds [1.0, 0.5]. The cost model is *depth-bound*: the per-step fixed
overhead (kernel launches + attention setup across 28 blocks) dominates the
per-row work, which is exactly the regime where patch parallelism stops
scaling — every patch worker pays the full fixed cost no matter how small
its slab, so the slow device bounds the step at ``t_fixed / v_min``. The
stage chain splits that cost in proportion to speed
(``hetero.stage_partition``), pays activation-sized point-to-point handoffs
instead of the staged-KV broadcast, and keeps the pipe full across
stale-async boundaries. Acceptance: >= 20% modeled end-to-end reduction vs
pure patch parallelism (the ``uniform`` planner). The full-STADI plan is
reported alongside for honesty — when temporal tiers can absorb the speed
skew, STADI remains competitive; the pipeline wins the depth/memory-bound
and excluded-device regimes.

Quality: real numerics on tiny-dit (de-degenerated adaLN so remote context
genuinely matters). Contract: ``pipefuse`` at one stage is BITWISE the
emulated engine, and the displaced (one-substep-stale) context at two
stages stays within 1 dB PSNR of the non-pipelined baseline.

Writes results/pipefuse.json (CI artifact).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import CostModel

# 2-tier heterogeneous cluster: fast node + half-speed node. Depth-bound
# cost model: one full-depth step has ~45 ms fixed overhead (28 blocks) vs
# ~13 ms of row work at the full 64-row image on the fast node.
OCCUPANCIES = [0.0, 0.5]
CLUSTER_CM = CostModel(t_fixed=45e-3, t_row=2e-4,
                       link_bw=25e9, link_latency=30e-6)
M_BASE_LAT, M_WARMUP_LAT = 100, 4
# every plan runs under DistriFusion-style stale-async boundaries (one
# corrective refresh every REFRESH) — pure patch parallelism IS stale-async,
# and skip boundaries are what keep the displaced pipe full between drains
REFRESH = 8


def modeled_latency(m_base: int, m_warmup: int):
    cfg = get_config("sdxl-dit")
    base = StadiConfig.from_occupancies(
        OCCUPANCIES, m_base=m_base, m_warmup=m_warmup, backend="simulate",
        cost_model=CLUSTER_CM, granularity=2,   # paper's P_total=32 slabs
        exchange="stale_async", exchange_refresh=REFRESH)
    runs = {
        "uniform_pp": dataclasses.replace(base, planner="uniform"),
        "stadi": dataclasses.replace(base, planner="stadi"),
        "pipefuse_s2": dataclasses.replace(base, planner="stadi_pipefuse",
                                           num_stages=2),
        "pipefuse_auto": dataclasses.replace(base, planner="stadi_pipefuse",
                                             num_stages=0),
    }
    out = {}
    for name, config in runs.items():
        pipe = StadiPipeline(cfg, None, None, config)
        res = pipe.generate()
        out[name] = {"latency_s": res.latency_s,
                     "stages": res.plan.stages,
                     "patches": res.plan.patches}
    for name in runs:
        out[name]["reduction_vs_uniform_pct"] = (
            (1.0 - out[name]["latency_s"] / out["uniform_pp"]["latency_s"])
            * 100.0)
    return out


def quality(m_base: int, m_warmup: int):
    """Bitwise S=1 parity + displaced-drift PSNR on real numerics."""
    from repro.models.diffusion import dit
    cfg = get_config("tiny-dit").reduced()
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=100)
    B = 2
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (B, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.arange(B, dtype=jnp.int32) % cfg.n_classes
    origin = np.asarray(pp.run_origin(params, cfg, sched, x_T, cond, m_base))
    base = StadiConfig.from_occupancies(OCCUPANCIES, m_base=m_base,
                                        m_warmup=m_warmup,
                                        exchange="stale_async",
                                        exchange_refresh=4)
    emu = np.asarray(StadiPipeline(cfg, params, sched,
                                   base).generate(x_T, cond).image)
    s1 = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(base, backend="pipefuse")).generate(
            x_T, cond).image)
    s2 = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(base, backend="pipefuse",
                            num_stages=2)).generate(x_T, cond).image)
    out = {
        "s1_bitwise_vs_emulated": bool(np.array_equal(s1, emu)),
        "emulated": {"psnr_vs_origin_db": common.psnr(emu, origin)},
        "pipefuse_s2": {"psnr_vs_origin_db": common.psnr(s2, origin)},
        "displaced_drift_max": float(np.abs(s2 - emu).max()),
    }
    out["pipefuse_s2"]["psnr_drift_vs_emulated_db"] = (
        out["emulated"]["psnr_vs_origin_db"]
        - out["pipefuse_s2"]["psnr_vs_origin_db"])
    return out


def run(emit: bool = True):
    smoke = common.smoke()
    lat = modeled_latency(m_base=20 if smoke else M_BASE_LAT,
                          m_warmup=2 if smoke else M_WARMUP_LAT)
    qual = quality(m_base=8 if smoke else 16, m_warmup=2 if smoke else 4)
    if emit:
        for name, d in lat.items():
            common.emit(f"pipefuse/{name}/latency", d["latency_s"] * 1e6,
                        f"reduction={d['reduction_vs_uniform_pct']:.1f}% "
                        f"stages={d['stages']}")
        drift_db = qual["pipefuse_s2"]["psnr_drift_vs_emulated_db"]
        common.emit("pipefuse/s2/psnr",
                    qual["pipefuse_s2"]["psnr_vs_origin_db"],
                    f"drift={drift_db:+.2f}dB")
    payload = {
        "cluster": {"occupancies": OCCUPANCIES,
                    "cost_model": dataclasses.asdict(CLUSTER_CM)},
        "latency_arch": "sdxl-dit", "quality_arch": "tiny-dit(reduced)",
        "latency": lat, "quality": qual,
    }
    common.write_json("pipefuse.json", payload)
    return payload


def main():
    res = run()
    lat, qual = res["latency"], res["quality"]
    red = lat["pipefuse_s2"]["reduction_vs_uniform_pct"]
    print(f"# pipefuse(S=2) modeled reduction vs pure patch parallelism: "
          f"{red:.1f}% (acceptance: >= 20%)")
    print(f"# stadi reduction vs uniform: "
          f"{lat['stadi']['reduction_vs_uniform_pct']:.1f}% | auto planner "
          f"chose stages={lat['pipefuse_auto']['stages']}")
    drift = qual["pipefuse_s2"]["psnr_drift_vs_emulated_db"]
    print(f"# displaced S=2: PSNR "
          f"{qual['pipefuse_s2']['psnr_vs_origin_db']:.2f} dB "
          f"(drift {drift:+.2f} dB vs non-pipelined; bar < 1 dB)")
    assert qual["s1_bitwise_vs_emulated"], "S=1 must be bitwise-identical"
    assert red >= 20.0, (red, lat)
    assert qual["displaced_drift_max"] > 0.0, "displacement must be real"
    assert drift <= 1.0, (drift, qual)


if __name__ == "__main__":
    main()
