"""Beyond-paper extensions, measured (DESIGN.md §7):

1. Generalized LCM tiers {1,2,4} + makespan-optimal allocator vs the
   paper's Eq. 4 (+Eq. 5) on strongly-skewed 4-device clusters — the paper's
   2-tier quantization leaves latency on the table when speeds span > 4x.
2. Online re-profiling (EWMA v_i) under occupancy DRIFT: the paper profiles
   once before inference; if a background job lands mid-request, STADI's
   static plan goes stale. We re-plan at the interval boundary after the
   profiler detects drift and compare makespans.
"""
from __future__ import annotations

from benchmarks import common
from benchmarks.bench_latency import M_BASE, M_WARMUP, build_trace
from repro.core import hetero, simulate as sim
from repro.core.hetero import OnlineProfiler
from repro.core.schedule import (makespan_optimal_allocation,
                                 spatial_allocation, temporal_allocation)


def run(emit=True):
    cfg, params, sched = common.load_tiny_dit()
    cm = common.calibrate_cost_model(cfg, params)
    P = cfg.tokens_per_side
    out = {}

    # ---- 1. generalized tiers on skewed 4-device clusters ----------------
    for occ in ([0.0, 0.3, 0.55, 0.7], [0.0, 0.5, 0.6, 0.7], [0.1, 0.2, 0.6, 0.72]):
        speeds = hetero.speeds(hetero.make_cluster(occ))
        plan_p = temporal_allocation(speeds, M_BASE, M_WARMUP)
        patches_p = spatial_allocation(speeds, plan_p.steps, P)
        t_paper = sim.simulate_trace(build_trace(plan_p, patches_p, cfg), speeds, cm)
        plan_o, patches_o, _ = makespan_optimal_allocation(
            speeds, M_BASE, M_WARMUP, P,
            fixed_overhead=cm.t_fixed / (cm.t_fixed + cm.t_row * P))
        t_opt = sim.simulate_trace(build_trace(plan_o, patches_o, cfg), speeds, cm)
        gain = (1 - t_opt / t_paper) * 100
        key = f"tiers{occ}"
        out[key] = (t_paper, t_opt, gain, plan_p.ratios, plan_o.ratios)
        if emit:
            common.emit(f"beyond/tiers/{occ}", t_opt * 1e6,
                        f"paper={t_paper:.2f}s opt={t_opt:.2f}s gain={gain:.1f}% "
                        f"ratios {plan_p.ratios}->{plan_o.ratios}")

    # ---- 2. online re-profiling under occupancy drift ---------------------
    # device 1's occupancy jumps 0.0 -> 0.6 halfway through the request
    speeds_before = hetero.speeds(hetero.make_cluster([0.0, 0.0]))
    speeds_after = hetero.speeds(hetero.make_cluster([0.0, 0.6]))

    def staged_makespan(plan1, patches1, plan2, patches2):
        """First half executes plan1, second half plan2 (re-planned)."""
        tr1 = build_trace(plan1, patches1, cfg)
        tr2 = build_trace(plan2, patches2, cfg)
        half1 = tr1.events[:len(tr1.events) // 2]
        half2 = tr2.events[len(tr2.events) // 2:]
        tr1.events = half1
        tr2.events = half2
        return (sim.simulate_trace(tr1, speeds_before, cm) +
                sim.simulate_trace(tr2, speeds_after, cm))

    # static (paper): plan from pre-inference profile only
    plan_s = temporal_allocation(speeds_before, M_BASE, M_WARMUP)
    patches_s = spatial_allocation(speeds_before, plan_s.steps, P)
    t_static = staged_makespan(plan_s, patches_s, plan_s, patches_s)
    # adaptive: profiler observes slow intervals, re-plans with updated v
    prof = OnlineProfiler(list(speeds_before), alpha=1.0)
    prof.update(1, work=1.0, measured_time=1.0 / max(speeds_after[1], 1e-9))
    plan_a = temporal_allocation(prof.speeds, M_BASE, M_WARMUP)
    patches_a = spatial_allocation(prof.speeds, plan_a.steps, P)
    t_adapt = staged_makespan(plan_s, patches_s, plan_a, patches_a)
    gain = (1 - t_adapt / t_static) * 100
    out["drift"] = (t_static, t_adapt, gain)
    if emit:
        common.emit("beyond/online_reprofile", t_adapt * 1e6,
                    f"static={t_static:.2f}s adaptive={t_adapt:.2f}s "
                    f"gain={gain:.1f}% (occupancy 0->60% mid-request)")
    return out


def main():
    res = run()
    for key, v in res.items():
        if key.startswith("tiers"):
            t_paper, t_opt = v[0], v[1]
            assert t_opt <= t_paper * 1.001, (key, v)   # never worse
        else:
            t_static, t_adapt, gain = v
            assert t_adapt < t_static, v                # drift adaptation wins
    tier_gains = [v[2] for k, v in res.items() if k.startswith("tiers")]
    print(f"# generalized-tier gains vs paper Eq.4: "
          f"{[f'{g:.1f}%' for g in tier_gains]}")
    print(f"# online re-profiling gain under drift: {res['drift'][2]:.1f}%")


if __name__ == "__main__":
    main()
