"""Classifier-free guidance placement sweep (DESIGN.md §12): modeled
latency per guidance mode plus measured quality drift of interleaved
uncond reuse.

Latency: the ``"simulate"`` pipeline backend replays the guided schedule IR
for an SDXL-scale denoiser (sdxl-dit) on a 2-tier heterogeneous cluster —
two fast + two half-speed devices over commodity 10 GbE (1.25 GB/s), the
regime where the interval boundary is staged-K/V-bound. Fused-batch CFG
doubles every K/V payload and serializes both branches' broadcasts on one
fabric; guidance-split places the cond/uncond groups on disjoint fabric
domains so each broadcasts one branch's worth concurrently, and only the
latent-sized epsilon combine crosses — the acceptance bar is >= 20% modeled
end-to-end reduction for the guidance-aware (split) plan vs fused-batch
CFG.

Quality: the emulated engine runs real guided numerics on tiny-dit
(reduced, de-degenerated params) and reports PSNR vs the fused-batch CFG
Origin (``run_origin_cfg``). Split CFG is bitwise-identical to fused under
one schedule (tested in tests/test_guidance.py), so the interesting number
is INTERLEAVED uncond reuse: eps_u recomputed every other interval and
reused in between. The contract: interleaved PSNR drift vs the exact
split/fused schedule stays < 1 dB.

Writes results/guidance.json (CI artifact; ``--smoke`` shrinks steps).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import CostModel

# 2-tier heterogeneous cluster: two fast + two half-speed devices over
# commodity 10 GbE; per-step costs in the DiT-XL/2 class (as bench_exchange)
OCCUPANCIES = [0.0, 0.0, 0.5, 0.5]
CLUSTER_CM = CostModel(t_fixed=5e-3, t_row=5.5e-4,
                       link_bw=1.25e9, link_latency=50e-6)
M_BASE_LAT, M_WARMUP_LAT = 100, 4
CFG_SCALE = 5.0                   # production-typical guidance weight
UNCOND_REFRESH = 2                # interleaved: recompute eps_u every other


def modeled_latency(modes):
    """Modeled makespan per guidance mode on the 2-tier cluster profile."""
    cfg = get_config("sdxl-dit")
    base = StadiConfig.from_occupancies(
        OCCUPANCIES, m_base=M_BASE_LAT, m_warmup=M_WARMUP_LAT,
        backend="simulate", cost_model=CLUSTER_CM, granularity=2,
        planner="stadi_guidance", cfg_scale=CFG_SCALE,
        uncond_refresh=UNCOND_REFRESH)
    out = {}
    for mode in modes:
        config = dataclasses.replace(base, guidance=mode)
        res = StadiPipeline(cfg, None, None, config).generate()
        out[mode] = {"latency_s": res.latency_s,
                     "workers": len(res.plan.active),
                     "patches": list(res.plan.patches)}
    auto = StadiPipeline(cfg, None, None, base).generate()
    out["auto"] = {"latency_s": auto.latency_s,
                   "picked": auto.plan.guidance.mode}
    for mode in modes:
        out[mode]["reduction_vs_fused_pct"] = (
            (1.0 - out[mode]["latency_s"] / out["fused"]["latency_s"])
            * 100.0)
    return out


def quality(modes, m_base: int, m_warmup: int):
    """PSNR vs the fused-batch CFG Origin, real guided numerics."""
    cfg = get_config("tiny-dit").reduced()
    params = pp.dit.nondegenerate_params(
        pp.dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=100)
    B = 2
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (B, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.arange(B, dtype=jnp.int32) % cfg.n_classes
    scale = CFG_SCALE
    origin = np.asarray(pp.run_origin_cfg(params, cfg, sched, x_T, cond,
                                          m_base, scale))
    out = {}
    for mode in modes:
        config = StadiConfig.from_occupancies(
            OCCUPANCIES, m_base=m_base, m_warmup=m_warmup,
            planner="stadi_guidance", cfg_scale=scale, guidance=mode,
            uncond_refresh=UNCOND_REFRESH)
        img = np.asarray(StadiPipeline(cfg, params, sched,
                                       config).generate(x_T, cond).image)
        out[mode] = {"psnr_vs_origin_db": common.psnr(img, origin)}
    for mode in modes:
        out[mode]["psnr_drift_vs_split_db"] = (
            out["split"]["psnr_vs_origin_db"]
            - out[mode]["psnr_vs_origin_db"])
    return out


def run(emit: bool = True):
    smoke = common.smoke()
    modes = ["fused", "split", "interleaved"]
    lat = modeled_latency(modes)
    qual = quality(modes, m_base=8 if smoke else 16,
                   m_warmup=2 if smoke else 4)
    if emit:
        for mode in modes:
            common.emit(f"guidance/{mode}/latency",
                        lat[mode]["latency_s"] * 1e6,
                        f"reduction={lat[mode]['reduction_vs_fused_pct']:.1f}%")
            common.emit(f"guidance/{mode}/psnr",
                        qual[mode]["psnr_vs_origin_db"],
                        f"drift={qual[mode]['psnr_drift_vs_split_db']:.2f}dB")
    payload = {
        "cluster": {"occupancies": OCCUPANCIES,
                    "cost_model": dataclasses.asdict(CLUSTER_CM),
                    "cfg_scale": CFG_SCALE,
                    "uncond_refresh": UNCOND_REFRESH},
        "latency_arch": "sdxl-dit", "quality_arch": "tiny-dit(reduced)",
        "latency": lat, "quality": qual,
    }
    common.write_json("guidance.json", payload)
    return payload


def main():
    res = run()
    lat, qual = res["latency"], res["quality"]
    red = lat["split"]["reduction_vs_fused_pct"]
    print(f"# guidance-split modeled reduction vs fused-batch CFG: "
          f"{red:.1f}% (acceptance: >= 20%)  auto={lat['auto']['picked']}")
    for mode, q in qual.items():
        print(f"# {mode}: PSNR {q['psnr_vs_origin_db']:.2f} dB "
              f"(drift {q['psnr_drift_vs_split_db']:+.2f} dB vs split)")
    assert red >= 20.0, (red, lat)
    assert lat["auto"]["picked"] == "split", lat["auto"]
    drift = qual["interleaved"]["psnr_drift_vs_split_db"]
    assert drift < 1.0, (drift, qual)
    # split == fused numerics under one schedule is the tested bitwise
    # contract; here their PSNRs may differ (different plans), but both
    # must track the Origin closely
    assert qual["split"]["psnr_vs_origin_db"] > 20.0, qual


if __name__ == "__main__":
    main()
