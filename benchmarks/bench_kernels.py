"""Kernel microbenches: correctness deltas vs oracle + CPU wall-time of the
algorithmic stand-ins (naive vs chunked attention; scan vs chunked SSM).
Interpret-mode Pallas wall-time is NOT a TPU proxy — the derived column
reports max|err| vs the oracle and the analytic HBM-bytes saving instead."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref
from repro.models.attention import chunked_attend
from repro.models import layers


def run(emit=True):
    out = {}
    # flash attention kernel vs oracle
    B, S, H, hd = 1, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    got = ops.flash_attention(q, k, v, causal=True)
    want = jnp.moveaxis(ref.attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        causal=True), 1, 2)
    err = float(jnp.max(jnp.abs(got - want)))
    naive_bytes = B * H * S * S * 4
    flash_bytes = B * H * S * hd * 4 * 4
    if emit:
        common.emit("kernels/flash_attention", 0.0,
                    f"max_err={err:.2e} score-mem {naive_bytes/1e6:.1f}MB->"
                    f"{flash_bytes/1e6:.1f}MB")
    out["flash_err"] = err

    # stale-kv kernel vs oracle (the paper's hot op)
    N, Nl, st = 256, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    qf = jax.random.normal(ks[0], (B, Nl, H, hd))
    kf = jax.random.normal(ks[1], (B, Nl, H, hd))
    vf = jax.random.normal(ks[2], (B, Nl, H, hd))
    kst = jax.random.normal(ks[3], (B, N, H, hd))
    vst = jax.random.normal(ks[4], (B, N, H, hd))
    got = ops.stale_kv_attention(qf, kf, vf, kst, vst, tok_start=st)
    want = jnp.moveaxis(ref.stale_kv_attention_ref(
        jnp.moveaxis(qf, 2, 1), jnp.moveaxis(kf, 2, 1), jnp.moveaxis(vf, 2, 1),
        jnp.moveaxis(kst, 2, 1), jnp.moveaxis(vst, 2, 1), st), 1, 2)
    err = float(jnp.max(jnp.abs(got - want)))
    if emit:
        common.emit("kernels/stale_kv_attention", 0.0,
                    f"max_err={err:.2e} buffer-rewrite saved="
                    f"{2*N*H*hd*4/1e6:.2f}MB/step/layer")
    out["stale_err"] = err

    # chunked attention stand-in: wall time + memory vs naive (CPU-real)
    S2 = 1024
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q2 = jax.random.normal(ks[0], (1, S2, 4, 64))
    k2 = jax.random.normal(ks[1], (1, S2, 4, 64))
    v2 = jax.random.normal(ks[2], (1, S2, 4, 64))
    naive = jax.jit(lambda q, k, v: layers.attend(
        q, k, v, mask=layers.causal_mask(S2, S2, 0)))
    chunked = jax.jit(lambda q, k, v: chunked_attend(
        q, k, v, causal=True, chunk=128))
    t_n = common.time_fn(lambda: naive(q2, k2, v2))
    t_c = common.time_fn(lambda: chunked(q2, k2, v2))
    err = float(jnp.max(jnp.abs(naive(q2, k2, v2) - chunked(q2, k2, v2))))
    if emit:
        common.emit("kernels/attend_naive_s1024", t_n * 1e6, "CPU wall")
        common.emit("kernels/attend_chunked_s1024", t_c * 1e6,
                    f"CPU wall, max_err={err:.2e}")
    out["chunked_err"] = err

    # ssm kernel vs oracle
    B3, S3, Di, Nst = 1, 256, 256, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (B3, S3, Di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B3, S3, Di))) * 0.1
    b_t = jax.random.normal(ks[2], (B3, S3, Nst))
    c_t = jax.random.normal(ks[3], (B3, S3, Nst))
    a = -jnp.exp(jnp.linspace(-2, 1, Nst))[None].repeat(Di, 0)
    d_skip = jnp.ones((Di,))
    got = ops.ssm_scan(x, dt, b_t, c_t, a, d_skip)
    want = ref.ssm_scan_ref(x, dt, b_t, c_t, a, d_skip)
    err = float(jnp.max(jnp.abs(got - want)))
    state_hbm_naive = B3 * S3 * Di * Nst * 4
    state_hbm_chunk = B3 * (S3 // 64) * Di * Nst * 4
    if emit:
        common.emit("kernels/ssm_scan", 0.0,
                    f"max_err={err:.2e} state-HBM {state_hbm_naive/1e6:.1f}MB"
                    f"->{state_hbm_chunk/1e6:.1f}MB")
    out["ssm_err"] = err
    return out


def main():
    out = run()
    assert out["flash_err"] < 1e-4
    assert out["stale_err"] < 1e-4
    assert out["chunked_err"] < 1e-4
    assert out["ssm_err"] < 1e-3


if __name__ == "__main__":
    main()
