"""Kernel microbenches: parity vs oracle for every Pallas body, wall-time of
the fused paths vs their assemble-then-attend references, and analytic HBM
traffic deltas — written to ``results/kernels.json`` (the kernel perf
trajectory artifact, DESIGN.md §15).

Timing honesty: off-TPU the Pallas kernels run in interpret mode, whose
wall-clock is NOT a TPU proxy — the JSON labels every timing with
``timing_mode`` ("tpu-compiled" vs "cpu-interpret") and the reference paths
are always real jitted XLA, so only same-mode comparisons are meaningful.
On TPU (``STADI_PALLAS_INTERPRET=0`` or auto-detected) the same benches
compile for real.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import sampler as sampler_lib
from repro.kernels import ops, ref
from repro.models.attention import chunked_attend
from repro.models import layers


def _rand(key, *shapes):
    ks = jax.random.split(jax.random.PRNGKey(key), len(shapes))
    return [jax.random.normal(k, s) for k, s in zip(ks, shapes)]


def _padded_reference(q, kf, vf, kst, vst, tok_start, valid, n_tokens):
    """The unfused SPMD attend: mask-blend the local slab, materialize the
    whole-image K/V via dynamic_update_slice, masked dense attend — what
    dit.block_stack runs when the kernel is off."""
    Nl = q.shape[1]
    mask = (jnp.arange(Nl) < valid)[None, :, None, None]
    cur_k = jax.lax.dynamic_slice_in_dim(kst, tok_start, Nl, axis=1)
    cur_v = jax.lax.dynamic_slice_in_dim(vst, tok_start, Nl, axis=1)
    ku = jnp.where(mask, kf, cur_k)
    vu = jnp.where(mask, vf, cur_v)
    full_k = jax.lax.dynamic_update_slice_in_dim(kst, ku, tok_start, axis=1)
    full_v = jax.lax.dynamic_update_slice_in_dim(vst, vu, tok_start, axis=1)
    key_mask = (jnp.arange(kst.shape[1]) < n_tokens)[None, None, None, :]
    return layers.attend(q, full_k, full_v, mask=key_mask)


def run(emit=True):
    out = {}
    interp = ops._interpret()
    timing_mode = "cpu-interpret" if interp else "tpu-compiled"
    results = {"timing_mode": timing_mode,
               "note": ("interpret-mode kernel timings are NOT a TPU proxy; "
                        "reference timings are real jitted XLA"
                        if interp else "compiled TPU timings"),
               "cases": {}}

    # ---------------- parity: flash attention vs oracle ----------------
    B, S, H, hd = 1, 256, 4, 64
    q, k, v = _rand(0, (B, S, H, hd), (B, S, H, hd), (B, S, H, hd))
    got = ops.flash_attention(q, k, v, causal=True)
    want = jnp.moveaxis(ref.attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        causal=True), 1, 2)
    err = float(jnp.max(jnp.abs(got - want)))
    naive_bytes = B * H * S * S * 4
    flash_bytes = B * H * S * hd * 4 * 4
    if emit:
        common.emit("kernels/flash_attention", 0.0,
                    f"max_err={err:.2e} score-mem {naive_bytes/1e6:.1f}MB->"
                    f"{flash_bytes/1e6:.1f}MB")
    out["flash_err"] = err

    # ---------------- parity: static stale-kv vs oracle ----------------
    N, Nl, st = 256, 64, 128
    qf, kf, vf, kst, vst = _rand(1, (B, Nl, H, hd), (B, Nl, H, hd),
                                 (B, Nl, H, hd), (B, N, H, hd),
                                 (B, N, H, hd))
    got = ops.stale_kv_attention(qf, kf, vf, kst, vst, tok_start=st)
    want = jnp.moveaxis(ref.stale_kv_attention_ref(
        jnp.moveaxis(qf, 2, 1), jnp.moveaxis(kf, 2, 1), jnp.moveaxis(vf, 2, 1),
        jnp.moveaxis(kst, 2, 1), jnp.moveaxis(vst, 2, 1), st), 1, 2)
    err = float(jnp.max(jnp.abs(got - want)))
    if emit:
        common.emit("kernels/stale_kv_attention", 0.0,
                    f"max_err={err:.2e} buffer-rewrite saved="
                    f"{2*N*H*hd*4/1e6:.2f}MB/step/layer")
    out["stale_err"] = err

    # ------- fused padded stale-kv: parity + wall time vs reference -------
    # the shard_map hot op: padded local slab, scratch-padded buffers,
    # traced tok_start/valid_tokens
    Np = N + Nl
    qp, kfp, vfp, ksp, vsp = _rand(2, (B, Nl, H, hd), (B, Nl, H, hd),
                                   (B, Nl, H, hd), (B, Np, H, hd),
                                   (B, Np, H, hd))
    tok_start, valid = 128, 48

    fused = jax.jit(lambda ts, va: ops.stale_kv_attention_padded(
        qp, kfp, vfp, ksp, vsp, ts, va, n_tokens=N))
    unfused = jax.jit(lambda ts, va: _padded_reference(
        qp, kfp, vfp, ksp, vsp, ts, va, N))
    got = fused(tok_start, valid)
    want = unfused(tok_start, valid)
    err = float(jnp.max(jnp.abs(got - want)))
    out["padded_err"] = err
    t_fused = common.time_fn(lambda: fused(tok_start, valid))
    t_ref = common.time_fn(lambda: unfused(tok_start, valid))
    # reference materializes blended full_k/full_v in HBM (write, then
    # re-read in the dense attend); the kernel streams fresh+stale tiles
    itemsize = np.dtype(np.float32).itemsize
    hbm_saved = 2 * 2 * B * Np * H * hd * itemsize   # k+v, write+reread
    if emit:
        common.emit("kernels/stale_kv_padded_fused", t_fused * 1e6,
                    f"{timing_mode}, max_err={err:.2e}")
        common.emit("kernels/stale_kv_padded_reference", t_ref * 1e6,
                    "jitted blend+update_slice+attend")
    results["cases"]["stale_kv_padded"] = {
        "shape": {"B": B, "H": H, "hd": hd, "Nl": Nl, "Npad": Np,
                  "n_tokens": N},
        "max_err_vs_reference": err,
        "fused_wall_us": t_fused * 1e6,
        "reference_wall_us": t_ref * 1e6,
        "hbm_bytes_saved_per_layer_step": hbm_saved,
    }

    # ------- guided (branch-stacked) stale-kv: parity both modes -------
    g_ops = _rand(3, (2, B, Nl, H, hd), (2, B, Nl, H, hd),
                  (2, B, Nl, H, hd), (2, B, Np, H, hd), (2, B, Np, H, hd))
    qg, kfg, vfg, ksg, vsg = g_ops
    for uncond_fresh in (1, 0):
        got = ops.stale_kv_attention_guided(
            qg, kfg, vfg, ksg, vsg, tok_start, valid, uncond_fresh,
            n_tokens=N)
        want_c = _padded_reference(qg[0], kfg[0], vfg[0], ksg[0], vsg[0],
                                   tok_start, valid, N)
        # uncond_fresh=0 is the interleaved body: branch 1 attends pure
        # stale (its fresh slab masked out in-kernel)
        want_u = _padded_reference(qg[1], kfg[1], vfg[1], ksg[1], vsg[1],
                                   tok_start,
                                   valid if uncond_fresh else 0, N)
        err = float(jnp.max(jnp.abs(got - jnp.stack([want_c, want_u]))))
        out[f"guided_err_uf{uncond_fresh}"] = err
        if emit:
            common.emit(f"kernels/stale_kv_guided_uf{uncond_fresh}", 0.0,
                        f"max_err={err:.2e}")
        results["cases"][f"stale_kv_guided_uncond_fresh{uncond_fresh}"] = {
            "max_err_vs_reference": err}

    # ------- lse ring partial: parity of the streamed combine -------
    # two segments merged by log-sum-exp == one dense attend
    T_seg = 128
    qr, k1, v1, k2, v2 = _rand(4, (B, S, H, hd), (B, T_seg, H, hd),
                               (B, T_seg, H, hd), (B, T_seg, H, hd),
                               (B, T_seg, H, hd))
    valid2 = 96                                      # scratch tail on seg 2
    o1, l1 = ops.lse_attention(qr, k1, v1, T_seg)
    o2, l2 = ops.lse_attention(qr, k2, v2, valid2)
    m = jnp.maximum(l1, l2)
    w1, w2 = jnp.exp(l1 - m), jnp.exp(l2 - m)
    merged = ((o1 * w1[..., None] + o2 * w2[..., None])
              / (w1 + w2)[..., None])
    kcat = jnp.concatenate([k1, k2[:, :valid2]], axis=1)
    vcat = jnp.concatenate([v1, v2[:, :valid2]], axis=1)
    want = layers.attend(qr, kcat, vcat)
    err = float(jnp.max(jnp.abs(merged - want)))
    out["lse_err"] = err
    if emit:
        common.emit("kernels/lse_ring_partial", 0.0,
                    f"max_err={err:.2e} segment-mem "
                    f"{2*B*2*T_seg*H*hd*4/1e6:.1f}MB->"
                    f"{2*B*T_seg*H*hd*4/1e6:.1f}MB")
    results["cases"]["lse_ring_partial"] = {
        "max_err_vs_dense": err,
        "kv_bytes_per_member_assembled": 2 * B * 2 * T_seg * H * hd * itemsize,
        "kv_bytes_per_member_streamed": 2 * B * T_seg * H * hd * itemsize,
    }

    # ------- fused CFG epilogue: parity + wall time vs two-pass -------
    E = (1, 64, 64, 3) if common.smoke() else (1, 128, 128, 3)
    ec, eu = _rand(5, E, E)
    scale = 4.5
    fused_cfg = jax.jit(lambda a, b: ops.cfg_epilogue(a, b, scale))
    unfused_cfg = jax.jit(lambda a, b: (
        sampler_lib.cfg_combine(a, b, scale), sampler_lib.cfg_delta(a, b)))
    (gc, gd), (wc, wd) = fused_cfg(ec, eu), unfused_cfg(ec, eu)
    err = float(max(jnp.max(jnp.abs(gc - wc)), jnp.max(jnp.abs(gd - wd))))
    out["cfg_err"] = err
    t_fused = common.time_fn(lambda: fused_cfg(ec, eu))
    t_ref = common.time_fn(lambda: unfused_cfg(ec, eu))
    n_el = int(np.prod(E))
    # unfused: each branch read twice (combine pass + delta pass); fused:
    # each branch read once — writes identical
    hbm_saved = 2 * n_el * itemsize
    if emit:
        common.emit("kernels/cfg_epilogue_fused", t_fused * 1e6,
                    f"{timing_mode}, max_err={err:.2e}")
        common.emit("kernels/cfg_epilogue_reference", t_ref * 1e6,
                    "jitted cfg_combine+cfg_delta")
    results["cases"]["cfg_epilogue"] = {
        "shape": list(E),
        "max_err_vs_sampler": err,
        "fused_wall_us": t_fused * 1e6,
        "reference_wall_us": t_ref * 1e6,
        "hbm_bytes_saved_per_step": hbm_saved,
    }

    # ------- chunked attention stand-in (CPU-real timings) -------
    S2 = 1024
    q2, k2c, v2c = _rand(6, (1, S2, 4, 64), (1, S2, 4, 64), (1, S2, 4, 64))
    naive = jax.jit(lambda q, k, v: layers.attend(
        q, k, v, mask=layers.causal_mask(S2, S2, 0)))
    chunked = jax.jit(lambda q, k, v: chunked_attend(
        q, k, v, causal=True, chunk=128))
    t_n = common.time_fn(lambda: naive(q2, k2c, v2c))
    t_c = common.time_fn(lambda: chunked(q2, k2c, v2c))
    err = float(jnp.max(jnp.abs(naive(q2, k2c, v2c) - chunked(q2, k2c, v2c))))
    if emit:
        common.emit("kernels/attend_naive_s1024", t_n * 1e6, "CPU wall")
        common.emit("kernels/attend_chunked_s1024", t_c * 1e6,
                    f"CPU wall, max_err={err:.2e}")
    out["chunked_err"] = err

    # ---------------- parity: ssm scan vs oracle ----------------
    B3, S3, Di, Nst = 1, 256, 256, 16
    x, dt_r, b_t, c_t = _rand(7, (B3, S3, Di), (B3, S3, Di),
                              (B3, S3, Nst), (B3, S3, Nst))
    dt = jax.nn.softplus(dt_r) * 0.1
    a = -jnp.exp(jnp.linspace(-2, 1, Nst))[None].repeat(Di, 0)
    d_skip = jnp.ones((Di,))
    got = ops.ssm_scan(x, dt, b_t, c_t, a, d_skip)
    want = ref.ssm_scan_ref(x, dt, b_t, c_t, a, d_skip)
    err = float(jnp.max(jnp.abs(got - want)))
    state_hbm_naive = B3 * S3 * Di * Nst * 4
    state_hbm_chunk = B3 * (S3 // 64) * Di * Nst * 4
    if emit:
        common.emit("kernels/ssm_scan", 0.0,
                    f"max_err={err:.2e} state-HBM {state_hbm_naive/1e6:.1f}MB"
                    f"->{state_hbm_chunk/1e6:.1f}MB")
    out["ssm_err"] = err

    results["parity"] = {k: v for k, v in out.items()}
    if emit:
        common.write_json("kernels.json", results)
    return out


def main():
    out = run()
    assert out["flash_err"] < 1e-4
    assert out["stale_err"] < 1e-4
    assert out["padded_err"] < 1e-4
    assert out["guided_err_uf1"] < 1e-4
    assert out["guided_err_uf0"] < 1e-4
    assert out["lse_err"] < 1e-4
    assert out["cfg_err"] < 1e-5
    assert out["chunked_err"] < 1e-4
    assert out["ssm_err"] < 1e-3


if __name__ == "__main__":
    main()
