"""Paper Table II: generation quality — Origin vs Patch Parallelism vs STADI
at M_base in {100, 50}, patch splits {3:1, 2:2, 1:3} (scaled from the paper's
{24:8, 16:16, 8:24} of P_total=32 to our tiny-DiT P_total=16 as
{12:4, 8:8, 4:12}).

Metrics (protocol of DESIGN.md §6): PSNR w/ Origin + w/ ground truth,
LPIPS-proxy (random-CNN feature distance), FID-proxy (Frechet distance on
those features). Validated claim: STADI's quality is on par with patch
parallelism (FID gap < 1 paper-scale; here: STADI FID-proxy within 15% of
PP's and far below the untrained-model baseline)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import patch_parallel as pp
from repro.core import stadi as stadi_lib
from repro.data import SyntheticImages

M_WARMUP = 4
N_IMAGES = 8


def _sample_batch(cfg, seed):
    ds = SyntheticImages(size=cfg.latent_size, channels=cfg.channels,
                         n_classes=cfg.n_classes, seed=0)
    gt, cls = ds.sample(np.random.default_rng(seed + 7), N_IMAGES)
    x_T = jax.random.normal(jax.random.PRNGKey(seed),
                            (N_IMAGES, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    return gt, jnp.asarray(cls), x_T


def run(emit=True):
    cfg, params, sched = common.load_tiny_dit()
    feats = common.feature_extractor()
    gt, cls, x_T = _sample_batch(cfg, seed=123)
    P = cfg.tokens_per_side
    out = {}
    for m_base in (100, 50):
        origin = np.asarray(pp.run_origin(params, cfg, sched, x_T, cls, m_base))
        f_gt = np.asarray(feats(jnp.asarray(gt)))
        f_orig = np.asarray(feats(jnp.asarray(origin)))
        rows = {"origin": (origin, None)}
        res = pp.run_distrifusion(params, cfg, sched, x_T, cls, 2, m_base, M_WARMUP)
        rows["patch_par_8:8"] = (np.asarray(res.image), None)
        for split in ((12, 4), (8, 8), (4, 12)):
            # speeds chosen so Eq.5 reproduces the split with TA active
            # (fast:slow -> ratio-2 tier for the slow device)
            v_slow = 0.5
            from repro.core.schedule import TemporalPlan
            plan = TemporalPlan([m_base, (m_base + M_WARMUP) // 2], [1, 2],
                                [False, False], m_base, M_WARMUP)
            r = pp.run_schedule(params, cfg, sched, x_T, cls, plan, list(split))
            rows[f"stadi_{split[0]}:{split[1]}"] = (np.asarray(r.image), plan)
        for name, (img, _) in rows.items():
            ps_gt = common.psnr(img, gt)
            ps_or = common.psnr(img, origin) if name != "origin" else float("nan")
            lp = common.lpips_proxy(feats, img, origin) if name != "origin" else 0.0
            f_img = np.asarray(feats(jnp.asarray(img)))
            fid_gt = common.frechet_proxy(f_img, f_gt)
            fid_or = common.frechet_proxy(f_img, f_orig)
            out[(m_base, name)] = dict(psnr_gt=ps_gt, psnr_orig=ps_or,
                                       lpips_orig=lp, fid_gt=fid_gt,
                                       fid_orig=fid_or)
            if emit:
                common.emit(f"quality/M{m_base}/{name}", 0.0,
                            f"psnr_gt={ps_gt:.2f} psnr_orig={ps_or:.2f} "
                            f"lpips={lp:.4f} fid_gt={fid_gt:.3f} "
                            f"fid_orig={fid_or:.3f}")
    return out


def main():
    res = run()
    for m_base in (100, 50):
        pp_fid = res[(m_base, "patch_par_8:8")]["fid_gt"]
        or_fid = res[(m_base, "origin")]["fid_gt"]
        for name in ("stadi_12:4", "stadi_8:8", "stadi_4:12"):
            st = res[(m_base, name)]
            # Table II claim: STADI fid-vs-GT within a small gap of PP/Origin
            assert st["fid_gt"] < max(pp_fid, or_fid) * 1.5 + 1.0, (name, st)
            # and semantically close to the origin output
            assert st["psnr_orig"] > 12.0, (name, st)
    print("# quality parity: STADI ~ PatchParallel ~ Origin (Table II analogue)")


if __name__ == "__main__":
    main()
