"""Paper Table III: ablation None / +SA / +TA / +TA+SA on occupancies
[0,20], [0,40], [0,60]; speedups vs None. Paper: SA alone 1.12-1.34x,
TA alone up to 1.82x, TA+SA lowest latency everywhere."""
from __future__ import annotations

from benchmarks import common
from benchmarks.bench_latency import M_BASE, M_WARMUP, build_trace
from repro.core import hetero, simulate as sim
from repro.core.patch_parallel import uniform_plan
from repro.core.schedule import spatial_allocation, temporal_allocation


def variant_trace(cfg, speeds, temporal: bool, spatial: bool):
    P_total = cfg.tokens_per_side
    n = len(speeds)
    plan = (temporal_allocation(speeds, M_BASE, M_WARMUP) if temporal
            else uniform_plan(n, M_BASE, M_WARMUP))
    patches = (spatial_allocation(speeds, plan.steps, P_total) if spatial
               else [P_total // n] * n)
    return build_trace(plan, patches, cfg)


def run(emit=True):
    cfg, params, sched = common.load_tiny_dit()
    cm = common.calibrate_cost_model(cfg, params)
    out = {}
    for occ in ([0.0, 0.2], [0.0, 0.4], [0.0, 0.6]):
        speeds = hetero.speeds(hetero.make_cluster(occ))
        lat = {}
        for name, (ta, sa) in {"none": (False, False), "+SA": (False, True),
                               "+TA": (True, False), "+TA+SA": (True, True)}.items():
            t = sim.simulate_trace(variant_trace(cfg, speeds, ta, sa), speeds, cm)
            lat[name] = t
        key = f"[{int(occ[0]*100)},{int(occ[1]*100)}]"
        out[key] = lat
        if emit:
            for name, t in lat.items():
                sp = lat["none"] / t
                common.emit(f"ablation/{key}/{name}", t * 1e6,
                            f"{t:.2f}s speedup={sp:.2f}x")
    return out


def main():
    res = run()
    for key, lat in res.items():
        assert lat["+TA+SA"] <= min(lat.values()) * 1.001, (key, lat)
        assert lat["+SA"] <= lat["none"], (key, lat)
        assert lat["+TA"] <= lat["none"], (key, lat)
    # heavier heterogeneity => larger TA benefit (paper's trend)
    sp60 = res["[0,60]"]["none"] / res["[0,60]"]["+TA"]
    sp20 = res["[0,20]"]["none"] / res["[0,20]"]["+TA"]
    print(f"# +TA speedup @[0,60] {sp60:.2f}x vs @[0,20] {sp20:.2f}x "
          f"(paper: 1.82x vs 1.32x)")
    assert sp60 > sp20


if __name__ == "__main__":
    main()
