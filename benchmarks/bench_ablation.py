"""Paper Table III: ablation None / +SA / +TA / +TA+SA on occupancies
[0,20], [0,40], [0,60]; speedups vs None. Paper: SA alone 1.12-1.34x,
TA alone up to 1.82x, TA+SA lowest latency everywhere.

Every variant is one planner name on the same ``StadiPipeline``:
none -> "uniform", +SA -> "spatial", +TA -> "temporal", +TA+SA -> "stadi".
"""
from __future__ import annotations

import dataclasses

from benchmarks import common
from benchmarks.bench_latency import M_BASE, M_WARMUP
from repro.core.pipeline import StadiConfig, StadiPipeline

VARIANTS = {"none": "uniform", "+SA": "spatial",
            "+TA": "temporal", "+TA+SA": "stadi"}


def run(emit=True):
    cfg, params, sched = common.load_tiny_dit()
    cm = common.calibrate_cost_model(cfg, params)
    out = {}
    for occ in ([0.0, 0.2], [0.0, 0.4], [0.0, 0.6]):
        config = StadiConfig.from_occupancies(
            occ, m_base=M_BASE, m_warmup=M_WARMUP, backend="simulate",
            cost_model=cm)
        lat = {}
        for name, planner in VARIANTS.items():
            pipe = StadiPipeline(cfg, params, sched,
                                 dataclasses.replace(config, planner=planner))
            lat[name] = pipe.generate().latency_s
        key = f"[{int(occ[0]*100)},{int(occ[1]*100)}]"
        out[key] = lat
        if emit:
            for name, t in lat.items():
                sp = lat["none"] / t
                common.emit(f"ablation/{key}/{name}", t * 1e6,
                            f"{t:.2f}s speedup={sp:.2f}x")
    return out


def main():
    res = run()
    for key, lat in res.items():
        assert lat["+TA+SA"] <= min(lat.values()) * 1.001, (key, lat)
        assert lat["+SA"] <= lat["none"], (key, lat)
        assert lat["+TA"] <= lat["none"], (key, lat)
    # heavier heterogeneity => larger TA benefit (paper's trend)
    sp60 = res["[0,60]"]["none"] / res["[0,60]"]["+TA"]
    sp20 = res["[0,20]"]["none"] / res["[0,20]"]["+TA"]
    print(f"# +TA speedup @[0,60] {sp60:.2f}x vs @[0,20] {sp20:.2f}x "
          f"(paper: 1.82x vs 1.32x)")
    assert sp60 > sp20


if __name__ == "__main__":
    main()
