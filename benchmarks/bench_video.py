"""Video / multi-frame diffusion sweep (DESIGN.md §16): modeled latency of
frame-parallel placement vs frame-sequential pure patch parallelism on a
2-tier heterogeneous cluster, plus measured cross-frame staleness quality.

Latency: the ``"simulate"`` backend replays the frame-priced schedule IR
for the high-resolution sdxl-dit on two fast + two half-speed nodes. The
cost model is *attention-bound*: every frame beyond the first attends the
doubled cross-frame context (own + previous frame's published K/V), so a
frame-sequential plan makes EVERY worker read ``(2F - 1) * p_total``
context rows per substep — and the slow device pays that whole read.
Frame-parallel member rows split the frame set speed-proportionally
(``frame_partition``): each row reads only its own frames' contexts, at
the price of one cross-row prev-frame K/V handoff per boundary; the
``stadi_video`` planner weighs the two with the frame cost model and
picks the grouping. Acceptance: >= 20% modeled end-to-end reduction vs
frame-sequential pure patch parallelism on the same cluster. The
frame-sequential STADI plan is reported alongside for honesty — in
compute-bound regimes (t_ctx ~ 0) the planner correctly refuses to split.

Quality: real numerics on tiny-dit, F = 3. The emulated reference is
bitwise placement-invariant (the frame grouping repartitions WHERE frames
run, never WHAT is computed), so the only quality lever is the
stale_async boundary policy's cross-frame stale K/V — measured as PSNR
drift vs the single-device sync origin, bar < 1 dB. ``num_frames=1`` must
stay BITWISE identical to the pre-frame image path.

Writes results/video.json (CI artifact).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import CostModel

# 2-tier heterogeneous cluster: two fast nodes + two at half speed.
# Attention-bound cost model (same shape as bench_seqpar): the cross-frame
# context read (t_ctx * ctx_rows) dominates the per-row work, so splitting
# the frame set across member rows — not splitting patches finer — is what
# cuts the wall.
OCCUPANCIES = [0.0, 0.0, 0.5, 0.5]
CLUSTER_CM = CostModel(t_fixed=2e-3, t_row=1e-4, t_ctx=3e-4,
                       link_bw=50e9, link_latency=20e-6)
M_BASE_LAT, M_WARMUP_LAT = 100, 4
F_LAT = 4                    # modeled clip length
F_QUAL = 3                   # measured clip length (real numerics)
REFRESH = 4


def modeled_latency(m_base: int, m_warmup: int):
    cfg = get_config("sdxl-dit")
    base = StadiConfig.from_occupancies(
        OCCUPANCIES, m_base=m_base, m_warmup=m_warmup, backend="simulate",
        cost_model=CLUSTER_CM, exchange="stale_async",
        exchange_refresh=REFRESH, num_frames=F_LAT)
    runs = {
        # frame-sequential pure patch parallelism: every worker runs all
        # F frames back-to-back (the baseline the acceptance bar is
        # measured against)
        "stadi_fseq": dataclasses.replace(base, planner="stadi"),
        "stadi_video_g2": dataclasses.replace(base, planner="stadi_video",
                                              frame_groups=2),
        "stadi_video_auto": dataclasses.replace(base, planner="stadi_video",
                                                frame_groups=0),
    }
    out = {}
    for name, config in runs.items():
        pipe = StadiPipeline(cfg, None, None, config)
        res = pipe.generate()
        fplan = res.plan.frames
        out[name] = {"latency_s": res.latency_s,
                     "patches": res.plan.patches,
                     "frame_groups": list(fplan.groups) if fplan else None}
    for name in runs:
        out[name]["reduction_vs_fseq_pct"] = (
            (1.0 - out[name]["latency_s"] / out["stadi_fseq"]["latency_s"])
            * 100.0)
    return out


def quality(m_base: int, m_warmup: int):
    """Placement invariance + cross-frame staleness PSNR, real numerics."""
    from repro.models.diffusion import dit
    cfg = get_config("tiny-dit").reduced()
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=100)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (1, F_QUAL, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.array([1])
    base = StadiConfig.from_occupancies(
        [0.0, 0.2, 0.4, 0.5], m_base=m_base, m_warmup=m_warmup,
        planner="stadi_video", num_frames=F_QUAL, exchange="sync")
    # single-device sync origin: the undisplaced multi-frame trajectory
    origin = np.asarray(StadiPipeline(
        cfg, params, sched,
        StadiConfig.from_occupancies([0.0], m_base=m_base,
                                     m_warmup=m_warmup,
                                     num_frames=F_QUAL)).generate(
            x_T, cond).image)
    sync = np.asarray(StadiPipeline(cfg, params, sched,
                                    dataclasses.replace(
                                        base, frame_groups=1)).generate(
        x_T, cond).image)
    # placement invariance: the frame grouping repartitions WHERE frames
    # run, never WHAT is computed — with the (temporal, patches) plan held
    # fixed, frame-sequential and frame-parallel groupings are bitwise
    # identical (different groupings PLAN differently, so the comparison
    # must pin the plan, not the planner)
    from repro.core import frames as frames_lib
    plan = StadiPipeline(cfg, params, sched,
                         dataclasses.replace(base, frame_groups=2)).plan()
    seq_img = frames_lib.run_frames(
        params, cfg, sched, x_T, cond, plan.temporal, plan.patches,
        frames=frames_lib.FramePlan(F_QUAL, (F_QUAL,))).image
    par_img = frames_lib.run_frames(
        params, cfg, sched, x_T, cond, plan.temporal, plan.patches,
        frames=plan.frames).image
    stale = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(base, frame_groups=1, exchange="stale_async",
                            exchange_refresh=REFRESH)).generate(
            x_T, cond).image)
    # num_frames=1 must be BITWISE the pre-frame image path
    img_cfg = StadiConfig.from_occupancies([0.0, 0.2, 0.4, 0.5],
                                           m_base=m_base, m_warmup=m_warmup)
    x1 = x_T[:, 0]
    image = np.asarray(StadiPipeline(cfg, params, sched,
                                     img_cfg).generate(x1, cond).image)
    video1 = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(img_cfg, num_frames=1)).generate(
            x1, cond).image)
    out = {
        "g2_bitwise_vs_g1": bool(np.array_equal(np.asarray(par_img),
                                                np.asarray(seq_img))),
        "f1_bitwise_vs_image": bool(np.array_equal(video1, image)),
        "sync": {"psnr_vs_origin_db": common.psnr(sync, origin)},
        "stale": {"psnr_vs_origin_db": common.psnr(stale, origin)},
    }
    out["stale"]["psnr_drift_vs_sync_db"] = (
        out["sync"]["psnr_vs_origin_db"]
        - out["stale"]["psnr_vs_origin_db"])
    return out


def run(emit: bool = True):
    smoke = common.smoke()
    lat = modeled_latency(m_base=20 if smoke else M_BASE_LAT,
                          m_warmup=2 if smoke else M_WARMUP_LAT)
    qual = quality(m_base=8 if smoke else 16, m_warmup=2 if smoke else 4)
    if emit:
        for name, d in lat.items():
            common.emit(f"video/{name}/latency", d["latency_s"] * 1e6,
                        f"reduction={d['reduction_vs_fseq_pct']:.1f}% "
                        f"groups={d['frame_groups']}")
        drift_db = qual["stale"]["psnr_drift_vs_sync_db"]
        common.emit("video/stale/psnr", qual["stale"]["psnr_vs_origin_db"],
                    f"drift={drift_db:+.2f}dB")
    payload = {
        "cluster": {"occupancies": OCCUPANCIES,
                    "cost_model": dataclasses.asdict(CLUSTER_CM)},
        "num_frames": {"latency": F_LAT, "quality": F_QUAL},
        "latency_arch": "sdxl-dit", "quality_arch": "tiny-dit(reduced)",
        "latency": lat, "quality": qual,
    }
    common.write_json("video.json", payload)
    return payload


def main():
    res = run()
    lat, qual = res["latency"], res["quality"]
    red = lat["stadi_video_auto"]["reduction_vs_fseq_pct"]
    print(f"# stadi_video(auto) modeled reduction vs frame-sequential "
          f"patch parallelism: {red:.1f}% (acceptance: >= 20%) — picked "
          f"groups={lat['stadi_video_auto']['frame_groups']} "
          f"patches={lat['stadi_video_auto']['patches']}")
    print(f"# pinned G=2 reduction: "
          f"{lat['stadi_video_g2']['reduction_vs_fseq_pct']:.1f}%")
    drift = qual["stale"]["psnr_drift_vs_sync_db"]
    print(f"# stale_async cross-frame K/V: PSNR "
          f"{qual['stale']['psnr_vs_origin_db']:.2f} dB "
          f"(drift {drift:+.2f} dB vs synchronous; bar < 1 dB)")
    assert qual["g2_bitwise_vs_g1"], \
        "emulated reference must be frame-placement invariant (bitwise)"
    assert qual["f1_bitwise_vs_image"], \
        "num_frames=1 must be bitwise the pre-frame image path"
    assert red >= 20.0, (red, lat)
    assert drift < 1.0, (drift, qual)


if __name__ == "__main__":
    main()
