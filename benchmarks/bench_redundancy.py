"""Paper Theorems 1-2: temporal redundancy on the TRAINED tiny DiT.

Thm 1: max_m |x_{t_m} - x_{t_{m+1}}| = O(1/M)  -> log-log slope ~ -1.
Thm 2: device j at 2x the steps of device i stays O(1/M)-aligned at shared
timesteps."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import sampler as sl
from repro.models.diffusion import dit


def run(emit=True):
    cfg, params, sched = common.load_tiny_dit()
    x_T = jax.random.normal(jax.random.PRNGKey(3),
                            (2, cfg.latent_size, cfg.latent_size, cfg.channels))
    cond = jnp.zeros((2,), jnp.int32)
    eps_fn = lambda x, t: dit.forward(params, cfg, x, t, cond)

    # Theorem 1
    Ms = [10, 20, 40, 80]
    diffs = []
    for M in Ms:
        _, traj = sl.ddim_sample(eps_fn, sched, x_T, M=M, collect=True)
        diffs.append(float(jnp.max(jnp.abs(jnp.diff(traj, axis=0)))))
    slope1 = float(np.polyfit(np.log(Ms), np.log(diffs), 1)[0])

    # Theorem 2: coarse (M/2) vs fine (M) trajectories at shared timesteps
    gaps = []
    for M in Ms:
        ts_f = sl.ddim_timesteps(sched.T, M)
        xf = xc = x_T
        worst = 0.0
        for m in range(M // 2):
            for s in range(2):
                tf, tt = ts_f[2 * m + s], ts_f[2 * m + s + 1]
                xf = sl.ddim_step(sched, xf, eps_fn(xf, tf), tf, tt)
            tcf, tct = ts_f[2 * m], ts_f[2 * m + 2]
            xc = sl.ddim_step(sched, xc, eps_fn(xc, tcf), tcf, tct)
            worst = max(worst, float(jnp.max(jnp.abs(xf - xc))))
        gaps.append(worst)
    slope2 = float(np.polyfit(np.log(Ms), np.log(gaps), 1)[0])

    if emit:
        for M, d, g in zip(Ms, diffs, gaps):
            common.emit(f"redundancy/M{M}", 0.0,
                        f"thm1_maxdiff={d:.4f} thm2_gap={g:.4f}")
        common.emit("redundancy/thm1_slope", 0.0, f"{slope1:.2f} (expect ~-1)")
        common.emit("redundancy/thm2_slope", 0.0, f"{slope2:.2f} (expect <=-0.5)")
    return slope1, slope2, diffs, gaps


def main():
    slope1, slope2, diffs, gaps = run()
    assert -1.6 < slope1 < -0.5, (slope1, diffs)
    assert slope2 < -0.4, (slope2, gaps)
    print(f"# Thm1 slope {slope1:.2f} (O(1/M) ok); Thm2 slope {slope2:.2f}")


if __name__ == "__main__":
    main()
