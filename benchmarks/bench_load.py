"""Load-generator harness for the diffusion serving engine (DESIGN.md §14).

Drives :class:`~repro.serving.diffusion_engine.DiffusionServingEngine` with
an open-loop arrival process in MODELED time (the engine's calibrated
cluster clock, so the curve is about scheduling, not host wall jitter):

  * Poisson and bursty-trace arrivals over a mixed request population —
    three SLO tiers (gold = CFG-guided + tight SLO, silver = unguided +
    relaxed SLO, bronze = unguided best-effort);
  * admission control: a queue-depth cap rejects work at saturation
    instead of letting latency diverge;
  * priority scheduling + preemption: queued gold requests jump the line
    and may evict an active bronze lane (``engine.preempt``) when every
    slot is busy;
  * an offered-load sweep producing the saturation-throughput curve
    (delivered rps, latency percentiles, per-tier SLO hit-rates,
    rejection/preemption counts vs offered rate);
  * the persistent plan cache: the sweep is planned twice against one
    cache directory — the second identical-workload sweep must be a 100%
    plan-cache hit-rate with zero planner searches.

Structured results go to ``results/load.json`` (uploaded as a CI artifact
by the bench-smoke job); summary rows go to the shared CSV.
"""
from __future__ import annotations

import os
import shutil
import sys
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.models.diffusion import dit
from repro.serving import DiffusionServingEngine

OCC = [0.0, 0.55]                  # 2-tier cluster, temporal ratios {1, 2}
SLOTS = 8
QUEUE_CAP = 2 * SLOTS              # admission control: reject past this depth
CACHE_DIR = os.path.join(common.RESULTS, "plan_cache")

#: (name, arrival weight, cfg_scale, SLO multiple of the unloaded latency,
#:  priority — lower preempts higher)
CLASSES: List[Tuple[str, float, float, Optional[float], int]] = [
    ("gold", 0.2, 3.0, 2.5, 0),
    ("silver", 0.5, 0.0, 6.0, 1),
    ("bronze", 0.3, 0.0, None, 2),
]
_PRIO = {name: prio for name, _, _, _, prio in CLASSES}


def _arrivals(rate: float, n: int, rng: np.random.Generator,
              trace: str = "poisson") -> List[Tuple[float, str]]:
    """n (arrival_time, class_name) pairs, sorted. ``poisson`` draws i.i.d.
    exponential gaps at ``rate``; ``bursty`` alternates 2.5x / 0.4x phases
    (same mean rate) so the queue sees real bursts."""
    names = [c[0] for c in CLASSES]
    weights = np.asarray([c[1] for c in CLASSES])
    kinds = rng.choice(names, size=n, p=weights / weights.sum())
    t, out = 0.0, []
    for i in range(n):
        if trace == "bursty":
            phase_rate = rate * (2.5 if (i // max(1, n // 6)) % 2 == 0 else 0.4)
        else:
            phase_rate = rate
        t += rng.exponential(1.0 / phase_rate)
        out.append((t, str(kinds[i])))
    return out


def _schedule(engine: DiffusionServingEngine, klass_of: Dict[int, str]) -> None:
    """Harness-level policy on top of the engine's FIFO: priority-order the
    queue, and let a queued gold request evict the youngest active bronze
    lane when every slot is taken."""
    engine.queue.sort(key=lambda r: _PRIO[klass_of[r.uid]])
    if (engine.queue and _PRIO[klass_of[engine.queue[0].uid]] == 0
            and len(engine.active) >= engine.slots):
        bronze = [r for r in engine.active.values()
                  if _PRIO[klass_of[r.uid]] == 2]
        if bronze:
            victim = min(bronze, key=lambda r: r.fine_step)  # least sunk work
            engine.preempt(victim.uid)
            engine.queue.sort(key=lambda r: _PRIO[klass_of[r.uid]])


def _run_point(pipe, cfg, rate: float, n: int, base_lat: float, seed: int,
               trace: str = "poisson") -> Dict:
    """Open-loop load at ``rate`` req/s (modeled) until the queue drains."""
    rng = np.random.default_rng(seed)
    arrivals = _arrivals(rate, n, rng, trace)
    engine = DiffusionServingEngine(pipe, slots=SLOTS)
    klass_of: Dict[int, str] = {}
    rejected = {c[0]: 0 for c in CLASSES}
    slo_of = {name: (mult * base_lat if mult is not None else None)
              for name, _, _, mult, _ in CLASSES}
    scale_of = {name: scale for name, _, scale, _, _ in CLASSES}
    i, peak_queue = 0, 0
    while i < len(arrivals) or engine.queue or engine.active:
        while i < len(arrivals) and arrivals[i][0] <= engine.modeled_clock_s:
            t_arr, name = arrivals[i]
            i += 1
            if len(engine.queue) >= QUEUE_CAP:
                rejected[name] += 1
                continue
            x = jax.random.normal(
                jax.random.PRNGKey(seed * 100_003 + i),
                (1, cfg.latent_size, cfg.latent_size, cfg.channels))
            req = engine.submit(x, int(rng.integers(0, cfg.n_classes)),
                                slo_s=slo_of[name],
                                cfg_scale=scale_of[name])
            klass_of[req.uid] = name
        if not engine.queue and not engine.active:
            engine.modeled_clock_s = max(engine.modeled_clock_s,
                                         arrivals[i][0])  # idle-skip to next
            continue
        _schedule(engine, klass_of)
        peak_queue = max(peak_queue, len(engine.queue))
        engine.step()
    done = engine.completed
    lats = np.asarray([r.modeled_latency_s for r in done])
    per_class = {}
    for name, _, _, mult, _ in CLASSES:
        rs = [r for r in done if klass_of[r.uid] == name]
        met = [r.slo_met for r in rs if r.slo_met is not None]
        per_class[name] = {
            "completed": len(rs),
            "rejected": rejected[name],
            "latency_p50_s": (float(np.percentile(
                [r.modeled_latency_s for r in rs], 50)) if rs else None),
            "slo_met_frac": (sum(met) / len(met)) if met else None,
        }
    return {
        "offered_rps": rate,
        "trace": trace,
        "n_offered": n,
        "delivered_rps": len(done) / engine.modeled_clock_s,
        "latency_p50_s": float(np.percentile(lats, 50)),
        "latency_p95_s": float(np.percentile(lats, 95)),
        "rejected": sum(rejected.values()),
        "preemptions": engine.stats()["preemptions"],
        "peak_queue": peak_queue,
        "classes": per_class,
    }


def _sweep_plans(cfg, params, sched, config) -> Dict:
    """Plan every sweep configuration through the shared cache directory and
    return {planner_calls, cache stats} — sweep 2 of the bench is this call
    hitting 100%."""
    pipe = StadiPipeline(cfg, params, sched, config)
    pipe.plan()
    return {"planner_calls": pipe.planner_calls, **pipe.plan_cache.stats()}


def run(emit: bool = True) -> Dict:
    smoke = common.smoke()
    m_base, m_warmup = (8, 2) if smoke else (16, 4)
    n_per_point = 32 if smoke else 400
    load_mults = [0.5, 2.0] if smoke else [0.25, 0.5, 1.0, 1.5, 2.0]
    cfg = get_config("tiny-dit").reduced()
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched = sampler_lib.linear_schedule(T=1000)
    cm = common.calibrate_cost_model(cfg, params)
    shutil.rmtree(CACHE_DIR, ignore_errors=True)   # deterministic miss count
    config = StadiConfig.from_occupancies(OCC, m_base=m_base,
                                          m_warmup=m_warmup, cost_model=cm,
                                          plan_cache_dir=CACHE_DIR)
    pipe = StadiPipeline(cfg, params, sched, config)

    # unloaded reference latency (sets the SLO tiers) + capacity estimate
    probe = DiffusionServingEngine(pipe, slots=SLOTS)
    for k in range(SLOTS):
        probe.submit(jax.random.normal(
            jax.random.PRNGKey(7 + k),
            (1, cfg.latent_size, cfg.latent_size, cfg.channels)),
            k % cfg.n_classes)
    probe.run_to_completion()
    base_lat = float(np.median([r.modeled_latency_s
                                for r in probe.completed]))
    capacity = len(probe.completed) / probe.modeled_clock_s

    curve = [_run_point(pipe, cfg, mult * capacity, n_per_point, base_lat,
                        seed=17 + k)
             for k, mult in enumerate(load_mults)]
    burst = _run_point(pipe, cfg, 0.75 * capacity, n_per_point, base_lat,
                       seed=41, trace="bursty")
    sweep1 = {"planner_calls": pipe.planner_calls, **pipe.plan_cache.stats()}

    # -- second identical-workload sweep: pure plan-cache hits -------------
    config2 = StadiConfig.from_occupancies(
        OCC, m_base=m_base, m_warmup=m_warmup, cost_model=cm,
        plan_cache_dir=CACHE_DIR)
    sweep2 = _sweep_plans(cfg, params, sched, config2)
    assert sweep2["planner_calls"] == 0 and sweep2["hit_rate"] == 1.0, sweep2

    payload = {
        "smoke": smoke,
        "cluster": {"occupancies": OCC, "slots": SLOTS,
                    "queue_cap": QUEUE_CAP,
                    "capacity_rps_modeled": capacity,
                    "base_latency_s": base_lat},
        "classes": [{"name": n, "weight": w, "cfg_scale": s,
                     "slo_x_base": m, "priority": p}
                    for n, w, s, m, p in CLASSES],
        "curve": curve,
        "bursty": burst,
        "plan_cache": {"sweep1": sweep1, "sweep2": sweep2},
    }
    common.write_json("load.json", payload)
    if emit:
        for row in curve:
            common.emit(f"load/x{row['offered_rps'] / capacity:.2f}",
                        row["latency_p95_s"] * 1e6,
                        f"delivered={row['delivered_rps']:.2f}rps "
                        f"rej={row['rejected']} pre={row['preemptions']}")
        common.emit("load/cache_sweep2", 0.0,
                    f"hit_rate={sweep2['hit_rate']:.2f} "
                    f"planner_calls={sweep2['planner_calls']}")
    return payload


def main():
    out = run()
    sat = out["curve"][-1]
    print(f"# saturation: offered {sat['offered_rps']:.2f} rps -> delivered "
          f"{sat['delivered_rps']:.2f} rps, p95 {sat['latency_p95_s']:.3f}s, "
          f"{sat['rejected']} rejected, {sat['preemptions']} preempted; "
          f"second sweep plan-cache hit-rate "
          f"{out['plan_cache']['sweep2']['hit_rate']:.0%}")


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        os.environ["STADI_BENCH_SMOKE"] = "1"
    main()
