"""Load-generator harness for the diffusion serving engine (DESIGN.md §14).

Drives :class:`~repro.serving.diffusion_engine.DiffusionServingEngine` with
an open-loop arrival process in MODELED time (the engine's calibrated
cluster clock, so the curve is about scheduling, not host wall jitter):

  * Poisson and bursty-trace arrivals over a mixed request population —
    three SLO tiers (gold = CFG-guided + tight SLO, silver = unguided +
    relaxed SLO, bronze = unguided best-effort);
  * admission control: a queue-depth cap rejects work at saturation
    instead of letting latency diverge;
  * priority scheduling + preemption: queued gold requests jump the line
    and may evict an active bronze lane (``engine.preempt``) when every
    slot is busy;
  * an offered-load sweep producing the saturation-throughput curve
    (delivered rps, latency percentiles, per-tier SLO hit-rates,
    rejection/preemption counts vs offered rate);
  * the persistent plan cache: the sweep is planned twice against one
    cache directory — the second identical-workload sweep must be a 100%
    plan-cache hit-rate with zero planner searches.

Structured results go to ``results/load.json`` (uploaded as a CI artifact
by the bench-smoke job); summary rows go to the shared CSV.
"""
from __future__ import annotations

import os
import shutil
import sys
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.models.diffusion import dit
from repro.serving import DiffusionServingEngine

OCC = [0.0, 0.55]                  # 2-tier cluster, temporal ratios {1, 2}
SLOTS = 8
QUEUE_CAP = 2 * SLOTS              # admission control: reject past this depth
CACHE_DIR = os.path.join(common.RESULTS, "plan_cache")

#: (name, arrival weight, cfg_scale, SLO multiple of the unloaded latency,
#:  priority — lower preempts higher)
CLASSES: List[Tuple[str, float, float, Optional[float], int]] = [
    ("gold", 0.2, 3.0, 2.5, 0),
    ("silver", 0.5, 0.0, 6.0, 1),
    ("bronze", 0.3, 0.0, None, 2),
]
_PRIO = {name: prio for name, _, _, _, prio in CLASSES}


def _arrivals(rate: float, n: int, rng: np.random.Generator,
              trace: str = "poisson") -> List[Tuple[float, str]]:
    """n (arrival_time, class_name) pairs, sorted. ``poisson`` draws i.i.d.
    exponential gaps at ``rate``; ``bursty`` alternates 2.5x / 0.4x phases
    (same mean rate) so the queue sees real bursts."""
    names = [c[0] for c in CLASSES]
    weights = np.asarray([c[1] for c in CLASSES])
    kinds = rng.choice(names, size=n, p=weights / weights.sum())
    t, out = 0.0, []
    for i in range(n):
        if trace == "bursty":
            phase_rate = rate * (2.5 if (i // max(1, n // 6)) % 2 == 0 else 0.4)
        else:
            phase_rate = rate
        t += rng.exponential(1.0 / phase_rate)
        out.append((t, str(kinds[i])))
    return out


def _schedule(engine: DiffusionServingEngine, klass_of: Dict[int, str]) -> None:
    """Harness-level policy on top of the engine's FIFO: priority-order the
    queue, and let a queued gold request evict the youngest active bronze
    lane when every slot is taken."""
    engine.queue.sort(key=lambda r: _PRIO[klass_of[r.uid]])
    if (engine.queue and _PRIO[klass_of[engine.queue[0].uid]] == 0
            and len(engine.active) >= engine.slots):
        bronze = [r for r in engine.active.values()
                  if _PRIO[klass_of[r.uid]] == 2]
        if bronze:
            victim = min(bronze, key=lambda r: r.fine_step)  # least sunk work
            engine.preempt(victim.uid)
            engine.queue.sort(key=lambda r: _PRIO[klass_of[r.uid]])


def _run_point(pipe, cfg, rate: float, n: int, base_lat: float, seed: int,
               trace: str = "poisson") -> Dict:
    """Open-loop load at ``rate`` req/s (modeled) until the queue drains."""
    rng = np.random.default_rng(seed)
    arrivals = _arrivals(rate, n, rng, trace)
    engine = DiffusionServingEngine(pipe, slots=SLOTS)
    klass_of: Dict[int, str] = {}
    rejected = {c[0]: 0 for c in CLASSES}
    slo_of = {name: (mult * base_lat if mult is not None else None)
              for name, _, _, mult, _ in CLASSES}
    scale_of = {name: scale for name, _, scale, _, _ in CLASSES}
    i, peak_queue = 0, 0
    while i < len(arrivals) or engine.queue or engine.active:
        while i < len(arrivals) and arrivals[i][0] <= engine.modeled_clock_s:
            t_arr, name = arrivals[i]
            i += 1
            if len(engine.queue) >= QUEUE_CAP:
                rejected[name] += 1
                continue
            x = jax.random.normal(
                jax.random.PRNGKey(seed * 100_003 + i),
                (1, cfg.latent_size, cfg.latent_size, cfg.channels))
            req = engine.submit(x, int(rng.integers(0, cfg.n_classes)),
                                slo_s=slo_of[name],
                                cfg_scale=scale_of[name])
            klass_of[req.uid] = name
        if not engine.queue and not engine.active:
            engine.modeled_clock_s = max(engine.modeled_clock_s,
                                         arrivals[i][0])  # idle-skip to next
            continue
        _schedule(engine, klass_of)
        peak_queue = max(peak_queue, len(engine.queue))
        engine.step()
    done = engine.completed
    lats = np.asarray([r.modeled_latency_s for r in done])
    per_class = {}
    for name, _, _, mult, _ in CLASSES:
        rs = [r for r in done if klass_of[r.uid] == name]
        met = [r.slo_met for r in rs if r.slo_met is not None]
        per_class[name] = {
            "completed": len(rs),
            "rejected": rejected[name],
            "latency_p50_s": (float(np.percentile(
                [r.modeled_latency_s for r in rs], 50)) if rs else None),
            "slo_met_frac": (sum(met) / len(met)) if met else None,
        }
    return {
        "offered_rps": rate,
        "trace": trace,
        "n_offered": n,
        "delivered_rps": len(done) / engine.modeled_clock_s,
        "latency_p50_s": float(np.percentile(lats, 50)),
        "latency_p95_s": float(np.percentile(lats, 95)),
        "rejected": sum(rejected.values()),
        "preemptions": engine.stats()["preemptions"],
        "peak_queue": peak_queue,
        "classes": per_class,
    }


def _clip_cost_s(cfg, params, sched, cm, m_base: int, m_warmup: int,
                 num_frames: int, seed: int = 5) -> float:
    """The frame-priced makespan of one run-to-completion video clip on
    this cluster — measured by actually serving a clip through a video
    engine built over the same occupancies and cost model."""
    config = StadiConfig.from_occupancies(
        OCC, m_base=m_base, m_warmup=m_warmup, cost_model=cm,
        planner="stadi_video", num_frames=num_frames)
    engine = DiffusionServingEngine(
        StadiPipeline(cfg, params, sched, config), slots=1)
    x = jax.random.normal(jax.random.PRNGKey(seed),
                          (1, num_frames, cfg.latent_size, cfg.latent_size,
                           cfg.channels))
    engine.submit(x, 1)
    done = engine.run_to_completion()
    return float(done[0].modeled_latency_s)


def _frame_preemption_point(pipe, cfg, base_lat: float, clip_cost: float,
                            use_preempt: bool, seed: int = 23) -> Dict:
    """The ROADMAP scenario: video lanes are run-to-completion, so a
    gold-tier image burst arriving behind a long clip has most of its SLO
    budget eaten before the image engine sees it — the only lever left is
    evicting mid-flight bronze lanes (``engine.preempt``). Bronze backlog
    fills every slot plus a full second generation; the clip blackout is
    charged to the modeled clock (the cluster serves the clip, image lanes
    stall); gold SLOs are measured from submission, clip included."""
    rng = np.random.default_rng(seed)
    engine = DiffusionServingEngine(pipe, slots=SLOTS)
    gold_slo = clip_cost + 3.0 * base_lat

    def _img(k: int):
        return jax.random.normal(
            jax.random.PRNGKey(seed * 211 + k),
            (1, cfg.latent_size, cfg.latent_size, cfg.channels))

    for k in range(3 * SLOTS):             # bronze: best-effort image work
        engine.submit(_img(k), int(rng.integers(0, cfg.n_classes)))
    engine.step()                          # bronze lanes are mid-flight
    gold_uids = set()
    for k in range(4):                     # the gold burst lands *behind*
        req = engine.submit(_img(100 + k),  # the clip's blackout window
                            int(rng.integers(0, cfg.n_classes)),
                            slo_s=gold_slo, cfg_scale=3.0)
        gold_uids.add(req.uid)
    engine.modeled_clock_s += clip_cost    # run-to-completion clip: the
    while engine.queue or engine.active:   # cluster is gone for clip_cost
        if use_preempt:
            gold_queued = sum(r.uid in gold_uids for r in engine.queue)
            bronze = sorted((r for r in engine.active.values()
                             if r.uid not in gold_uids),
                            key=lambda r: r.fine_step)   # least sunk work
            while (gold_queued > engine.slots - len(engine.active)
                   and bronze):
                engine.preempt(bronze.pop(0).uid)
            engine.queue.sort(key=lambda r: r.uid not in gold_uids)
        engine.step()
    gold = [r for r in engine.completed if r.uid in gold_uids]
    met = [bool(r.slo_met) for r in gold]
    return {
        "use_preempt": use_preempt,
        "gold_slo_s": gold_slo,
        "gold_completed": len(gold),
        "gold_slo_frac": sum(met) / len(met),
        "gold_latency_p50_s": float(np.percentile(
            [r.modeled_latency_s for r in gold], 50)),
        "preemptions": engine.stats()["preemptions"],
    }


def _sweep_plans(cfg, params, sched, config) -> Dict:
    """Plan every sweep configuration through the shared cache directory and
    return {planner_calls, cache stats} — sweep 2 of the bench is this call
    hitting 100%."""
    pipe = StadiPipeline(cfg, params, sched, config)
    pipe.plan()
    return {"planner_calls": pipe.planner_calls, **pipe.plan_cache.stats()}


def run(emit: bool = True) -> Dict:
    smoke = common.smoke()
    m_base, m_warmup = (8, 2) if smoke else (16, 4)
    n_per_point = 32 if smoke else 400
    load_mults = [0.5, 2.0] if smoke else [0.25, 0.5, 1.0, 1.5, 2.0]
    cfg = get_config("tiny-dit").reduced()
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched = sampler_lib.linear_schedule(T=1000)
    cm = common.calibrate_cost_model(cfg, params)
    shutil.rmtree(CACHE_DIR, ignore_errors=True)   # deterministic miss count
    config = StadiConfig.from_occupancies(OCC, m_base=m_base,
                                          m_warmup=m_warmup, cost_model=cm,
                                          plan_cache_dir=CACHE_DIR)
    pipe = StadiPipeline(cfg, params, sched, config)

    # unloaded reference latency (sets the SLO tiers) + capacity estimate
    probe = DiffusionServingEngine(pipe, slots=SLOTS)
    for k in range(SLOTS):
        probe.submit(jax.random.normal(
            jax.random.PRNGKey(7 + k),
            (1, cfg.latent_size, cfg.latent_size, cfg.channels)),
            k % cfg.n_classes)
    probe.run_to_completion()
    base_lat = float(np.median([r.modeled_latency_s
                                for r in probe.completed]))
    capacity = len(probe.completed) / probe.modeled_clock_s

    curve = [_run_point(pipe, cfg, mult * capacity, n_per_point, base_lat,
                        seed=17 + k)
             for k, mult in enumerate(load_mults)]
    burst = _run_point(pipe, cfg, 0.75 * capacity, n_per_point, base_lat,
                       seed=41, trace="bursty")
    sweep1 = {"planner_calls": pipe.planner_calls, **pipe.plan_cache.stats()}

    # -- frame-aware preemption (DESIGN.md §16/§17 serving composition) ----
    num_frames = 3
    clip_cost = _clip_cost_s(cfg, params, sched, cm, m_base, m_warmup,
                             num_frames)
    frame_pre = {
        "num_frames": num_frames,
        "clip_cost_s": clip_cost,
        "no_preempt": _frame_preemption_point(pipe, cfg, base_lat,
                                              clip_cost, False),
        "preempt": _frame_preemption_point(pipe, cfg, base_lat,
                                           clip_cost, True),
    }
    # the clip blackout alone must not sink gold (preemption saves them
    # all); without preemption the bronze backlog must sink them all
    assert frame_pre["preempt"]["gold_slo_frac"] == 1.0, frame_pre
    assert frame_pre["no_preempt"]["gold_slo_frac"] == 0.0, frame_pre

    # -- second identical-workload sweep: pure plan-cache hits -------------
    config2 = StadiConfig.from_occupancies(
        OCC, m_base=m_base, m_warmup=m_warmup, cost_model=cm,
        plan_cache_dir=CACHE_DIR)
    sweep2 = _sweep_plans(cfg, params, sched, config2)
    assert sweep2["planner_calls"] == 0 and sweep2["hit_rate"] == 1.0, sweep2

    payload = {
        "smoke": smoke,
        "cluster": {"occupancies": OCC, "slots": SLOTS,
                    "queue_cap": QUEUE_CAP,
                    "capacity_rps_modeled": capacity,
                    "base_latency_s": base_lat},
        "classes": [{"name": n, "weight": w, "cfg_scale": s,
                     "slo_x_base": m, "priority": p}
                    for n, w, s, m, p in CLASSES],
        "curve": curve,
        "bursty": burst,
        "frame_preemption": frame_pre,
        "plan_cache": {"sweep1": sweep1, "sweep2": sweep2},
    }
    common.write_json("load.json", payload)
    if emit:
        common.emit("load/frame_preempt/gold_slo",
                    frame_pre["preempt"]["gold_slo_frac"],
                    f"no_preempt={frame_pre['no_preempt']['gold_slo_frac']:.2f} "
                    f"clip={clip_cost * 1e3:.0f}ms "
                    f"pre={frame_pre['preempt']['preemptions']}")
        for row in curve:
            common.emit(f"load/x{row['offered_rps'] / capacity:.2f}",
                        row["latency_p95_s"] * 1e6,
                        f"delivered={row['delivered_rps']:.2f}rps "
                        f"rej={row['rejected']} pre={row['preemptions']}")
        common.emit("load/cache_sweep2", 0.0,
                    f"hit_rate={sweep2['hit_rate']:.2f} "
                    f"planner_calls={sweep2['planner_calls']}")
    return payload


def main():
    out = run()
    sat = out["curve"][-1]
    print(f"# saturation: offered {sat['offered_rps']:.2f} rps -> delivered "
          f"{sat['delivered_rps']:.2f} rps, p95 {sat['latency_p95_s']:.3f}s, "
          f"{sat['rejected']} rejected, {sat['preemptions']} preempted; "
          f"second sweep plan-cache hit-rate "
          f"{out['plan_cache']['sweep2']['hit_rate']:.0%}")
    fp = out["frame_preemption"]
    print(f"# frame-aware preemption: gold burst behind a "
          f"{fp['clip_cost_s'] * 1e3:.0f}ms run-to-completion clip -> gold "
          f"SLO hit rate {fp['no_preempt']['gold_slo_frac']:.0%} without / "
          f"{fp['preempt']['gold_slo_frac']:.0%} with engine.preempt "
          f"({fp['preempt']['preemptions']} bronze lanes evicted)")


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        os.environ["STADI_BENCH_SMOKE"] = "1"
    main()
