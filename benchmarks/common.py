"""Shared benchmark utilities: calibration, model loading, CSV emission."""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.core.simulate import CostModel, fit_cost_model
from repro.models.diffusion import dit

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results")
CKPT = os.path.join(RESULTS, "tiny_dit_ckpt")

_rows: List[str] = []


def smoke() -> bool:
    """Fast smoke mode (CI bench-smoke job): ``benchmarks.run --smoke`` sets
    STADI_BENCH_SMOKE=1; benches shrink step counts / request counts."""
    return os.environ.get("STADI_BENCH_SMOKE", "") not in ("", "0")


def write_json(name: str, payload: Dict) -> str:
    """Write a benchmark's structured results to results/<name> (artifact)."""
    import json
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)
    return path


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def flush_csv(path: str = None):
    path = path or os.path.join(RESULTS, "bench.csv")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(_rows) + "\n")


def load_tiny_dit(trained: bool = True):
    cfg = get_config("tiny-dit")
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    if trained and latest_step(CKPT) is not None:
        params = restore_checkpoint(CKPT, {"params": params})["params"]
        params = jax.tree.map(jnp.asarray, params)
    sched = sampler_lib.linear_schedule(T=1000)
    return cfg, params, sched


def time_fn(fn, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def calibrate_cost_model(cfg, params, rows_list=(4, 8, 16)) -> CostModel:
    """Measure real single-step denoiser latency at several patch sizes on
    this host; fit t(P) = t_fixed + t_row * P (DESIGN.md §6)."""
    wp = cfg.tokens_per_side
    p = cfg.patch_size
    B = 1
    buf_k, buf_v = dit.init_buffers(cfg, B)
    times, rows_used = [], []
    for rows in rows_list:
        if rows > wp:
            continue
        x = jnp.zeros((B, rows * p, cfg.latent_size, cfg.channels))
        cond = jnp.zeros((B,), jnp.int32)

        @jax.jit
        def step(x, bk, bv):
            eps, _ = dit.forward_patch(params, cfg, x, 500, cond, 0,
                                       buffers=(bk, bv))
            return eps

        t = time_fn(lambda: step(x, buf_k, buf_v))
        times.append(t)
        rows_used.append(rows)
    return fit_cost_model(rows_used, times)


def feature_extractor(seed: int = 0):
    """Fixed random-CNN feature map (LPIPS/FID proxy, DESIGN.md §6)."""
    from repro.models.diffusion.unet import conv2d
    rng = jax.random.PRNGKey(seed)
    ks = jax.random.split(rng, 3)
    w1 = jax.random.normal(ks[0], (3, 3, 3, 16)) / np.sqrt(27)
    w2 = jax.random.normal(ks[1], (3, 3, 16, 32)) / np.sqrt(144)
    w3 = jax.random.normal(ks[2], (3, 3, 32, 64)) / np.sqrt(288)

    @jax.jit
    def feats(x):
        h = jax.nn.relu(conv2d(x, w1, stride=2))
        h = jax.nn.relu(conv2d(h, w2, stride=2))
        h = jax.nn.relu(conv2d(h, w3, stride=2))
        return h.reshape(x.shape[0], -1)

    return feats


def psnr(a: np.ndarray, b: np.ndarray, data_range: float = 2.0) -> float:
    mse = float(np.mean((a - b) ** 2))
    if mse == 0:
        return 99.0
    return 10.0 * np.log10(data_range ** 2 / mse)


def frechet_proxy(fa: np.ndarray, fb: np.ndarray) -> float:
    """Frechet distance between Gaussians fit to feature sets (diagonal cov)."""
    mu_a, mu_b = fa.mean(0), fb.mean(0)
    va, vb = fa.var(0), fb.var(0)
    return float(np.sum((mu_a - mu_b) ** 2) +
                 np.sum(va + vb - 2 * np.sqrt(np.maximum(va * vb, 0))))


def lpips_proxy(feats, a: np.ndarray, b: np.ndarray) -> float:
    fa = np.asarray(feats(jnp.asarray(a)))
    fb = np.asarray(feats(jnp.asarray(b)))
    num = np.sum((fa - fb) ** 2, axis=1)
    den = np.sum(fa ** 2, axis=1) + np.sum(fb ** 2, axis=1) + 1e-9
    return float(np.mean(num / den))
