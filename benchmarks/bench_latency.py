"""Paper Fig. 8: end-to-end latency vs occupancy, STADI vs patch parallelism
vs tensor parallelism, on a 2-device cluster.

Scenario A (total resources decreasing): [0,20], [0,40], [0,60]
Scenario B (total fixed at 80%):         [35,45], [30,50], [25,55]

Cost model calibrated from real measured single-step DiT latencies on this
host (common.calibrate_cost_model); heterogeneous wall-clock is replayed by
the pipeline's ``"simulate"`` backend per DESIGN.md §2/§6. Reported: latency
(s) + STADI reduction vs PP — paper claims 12-45% (A) and 4-39% (B).
"""
from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.core import simulate as sim
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import build_trace  # noqa: F401  (bench_beyond et al.)

M_BASE, M_WARMUP = 100, 4


def run(cm=None, emit=True):
    cfg, params, sched = common.load_tiny_dit()
    if cm is None:
        cm = common.calibrate_cost_model(cfg, params)
    if emit:
        common.emit("latency/calib_t_fixed", cm.t_fixed * 1e6, "per-step fixed s")
        common.emit("latency/calib_t_row", cm.t_row * 1e6, "per-row s")
    P_total = cfg.tokens_per_side
    scenarios = {
        "A": [[0.0, 0.2], [0.0, 0.4], [0.0, 0.6]],
        "B": [[0.35, 0.45], [0.3, 0.5], [0.25, 0.55]],
    }
    out = {}
    for sc, grids in scenarios.items():
        for occ in grids:
            config = StadiConfig.from_occupancies(
                occ, m_base=M_BASE, m_warmup=M_WARMUP, backend="simulate",
                cost_model=cm)
            # patch parallelism: uniform everything
            t_pp = StadiPipeline(cfg, params, sched, dataclasses.replace(
                config, planner="uniform")).generate().latency_s
            # STADI
            t_st = StadiPipeline(cfg, params, sched,
                                 config).generate().latency_s
            # tensor parallelism baseline
            act_bytes = cfg.n_tokens * cfg.d_model * 2
            t_tp = sim.simulate_tensor_parallel(
                M_BASE, 2, cfg.n_layers, P_total, config.speeds, cm, act_bytes)
            red = (1 - t_st / t_pp) * 100
            key = f"{sc}[{int(occ[0]*100)},{int(occ[1]*100)}]"
            out[key] = (t_pp, t_tp, t_st, red)
            if emit:
                common.emit(f"latency/{key}/patch_par", t_pp * 1e6, f"{t_pp:.2f}s")
                common.emit(f"latency/{key}/tensor_par", t_tp * 1e6, f"{t_tp:.2f}s")
                common.emit(f"latency/{key}/stadi", t_st * 1e6,
                            f"{t_st:.2f}s reduction={red:.1f}%")
    return out


def main():
    res = run()
    reds_a = [v[3] for k, v in res.items() if k.startswith("A")]
    reds_b = [v[3] for k, v in res.items() if k.startswith("B")]
    print(f"# scenario A reductions: {[f'{r:.1f}%' for r in reds_a]} "
          f"(paper: 12-45%)")
    print(f"# scenario B reductions: {[f'{r:.1f}%' for r in reds_b]} "
          f"(paper: 4-39%)")
    # STADI must never lose to PP, and TP must trail both (paper Fig. 8)
    for k, (t_pp, t_tp, t_st, red) in res.items():
        assert t_st <= t_pp * 1.001, (k, t_st, t_pp)
        assert t_tp >= t_pp, (k, t_tp, t_pp)


if __name__ == "__main__":
    main()
