"""Paper Fig. 8: end-to-end latency vs occupancy, STADI vs patch parallelism
vs tensor parallelism, on a 2-device cluster.

Scenario A (total resources decreasing): [0,20], [0,40], [0,60]
Scenario B (total fixed at 80%):         [35,45], [30,50], [25,55]

Cost model calibrated from real measured single-step DiT latencies on this
host (common.calibrate_cost_model); heterogeneous wall-clock is simulated
per DESIGN.md §2/§6. Reported: latency (s) + STADI reduction vs PP —
paper claims 12-45% (A) and 4-39% (B).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import hetero, simulate as sim
from repro.core import stadi as stadi_lib
from repro.core.patch_parallel import uniform_plan
from repro.core.schedule import spatial_allocation, temporal_allocation
from repro.core.patch_parallel import ExecutionTrace, IntervalEvent

M_BASE, M_WARMUP = 100, 4


def build_trace(plan, patches, cfg, batch=1):
    """Schedule trace without running numerics (latency-only replay)."""
    R = plan.lcm
    F = plan.m_base - plan.m_warmup
    events = [IntervalEvent(m, [1 if not e else 0 for e in plan.excluded],
                            list(patches), synchronous=True)
              for m in range(plan.m_warmup)]
    for it in range(F // R):
        events.append(IntervalEvent(plan.m_warmup + it * R,
                                    [R // r if r else 0 for r in plan.ratios],
                                    list(patches)))
    H = cfg.latent_size
    lat_bytes = int(batch * H * H * cfg.channels * 4)
    kv_bytes = [int(2 * cfg.n_layers * batch * pr * cfg.tokens_per_side
                    * cfg.d_model * 2) for pr in patches]
    return ExecutionTrace(events, plan, list(patches), cfg.n_tokens,
                          lat_bytes, kv_bytes)


def run(cm=None, emit=True):
    cfg, params, sched = common.load_tiny_dit()
    if cm is None:
        cm = common.calibrate_cost_model(cfg, params)
    if emit:
        common.emit("latency/calib_t_fixed", cm.t_fixed * 1e6, "per-step fixed s")
        common.emit("latency/calib_t_row", cm.t_row * 1e6, "per-row s")
    P_total = cfg.tokens_per_side
    scenarios = {
        "A": [[0.0, 0.2], [0.0, 0.4], [0.0, 0.6]],
        "B": [[0.35, 0.45], [0.3, 0.5], [0.25, 0.55]],
    }
    out = {}
    for sc, grids in scenarios.items():
        for occ in grids:
            speeds = hetero.speeds(hetero.make_cluster(occ))
            # patch parallelism: uniform everything
            pp_plan = uniform_plan(2, M_BASE, M_WARMUP)
            pp_patches = [P_total // 2] * 2
            t_pp = sim.simulate_trace(build_trace(pp_plan, pp_patches, cfg),
                                      speeds, cm)
            # STADI
            plan = temporal_allocation(speeds, M_BASE, M_WARMUP)
            patches = spatial_allocation(speeds, plan.steps, P_total)
            t_st = sim.simulate_trace(build_trace(plan, patches, cfg),
                                      speeds, cm)
            # tensor parallelism baseline
            act_bytes = cfg.n_tokens * cfg.d_model * 2
            t_tp = sim.simulate_tensor_parallel(
                M_BASE, 2, cfg.n_layers, P_total, speeds, cm, act_bytes)
            red = (1 - t_st / t_pp) * 100
            key = f"{sc}[{int(occ[0]*100)},{int(occ[1]*100)}]"
            out[key] = (t_pp, t_tp, t_st, red)
            if emit:
                common.emit(f"latency/{key}/patch_par", t_pp * 1e6, f"{t_pp:.2f}s")
                common.emit(f"latency/{key}/tensor_par", t_tp * 1e6, f"{t_tp:.2f}s")
                common.emit(f"latency/{key}/stadi", t_st * 1e6,
                            f"{t_st:.2f}s reduction={red:.1f}%")
    return out


def main():
    res = run()
    reds_a = [v[3] for k, v in res.items() if k.startswith("A")]
    reds_b = [v[3] for k, v in res.items() if k.startswith("B")]
    print(f"# scenario A reductions: {[f'{r:.1f}%' for r in reds_a]} "
          f"(paper: 12-45%)")
    print(f"# scenario B reductions: {[f'{r:.1f}%' for r in reds_b]} "
          f"(paper: 4-39%)")
    # STADI must never lose to PP, and TP must trail both (paper Fig. 8)
    for k, (t_pp, t_tp, t_st, red) in res.items():
        assert t_st <= t_pp * 1.001, (k, t_st, t_pp)
        assert t_tp >= t_pp, (k, t_tp, t_pp)


if __name__ == "__main__":
    main()
