"""Sequence-parallel attention sweep (DESIGN.md §13): modeled latency of
Ulysses head-scatter + ring attention vs pure patch parallelism on a 2-tier
heterogeneous cluster, plus measured ring-staleness quality drift.

Latency: the ``"simulate"`` backend replays the schedule IR for the
high-resolution sdxl-dit on two fast + two half-speed nodes. The cost model
is *attention-bound*: at a 2K-class latent every patch worker's
self-attention reads the FULL token context with all heads regardless of
how few query rows it owns (``t_ctx * total_rows`` per substep), so patch
splits stop cutting the wall — the slow device pays the whole context read.
Head scattering divides exactly that term (each seq shard attends
``heads_frac`` of the heads), at the price of ``S - 1`` ring K/V hops per
substep; the ``stadi_seq`` planner weighs the two with the ring-contention
cost model and picks the shard count. Acceptance: >= 20% modeled end-to-end
reduction vs pure patch parallelism on the same cluster. The pure-patch
STADI plan is reported alongside for honesty — in compute-bound regimes
(t_ctx ~ 0) the planner correctly refuses to shard.

Quality: real numerics on tiny-dit. Contract: the emulated reference is
BITWISE shard-count invariant (the sequence dimension repartitions WHERE
attention runs, never WHAT is computed), so the only quality lever is the
"ring" boundary policy's stale cross-worker K/V — measured as PSNR drift vs
the single-device origin against the fully synchronous baseline, bar < 1 dB.

Writes results/seqpar.json (CI artifact).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import patch_parallel as pp
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import CostModel

# 2-tier heterogeneous cluster: two fast nodes + two at half speed.
# Attention-bound cost model: at the sdxl-dit's 64 token rows the
# full-context K/V read (t_ctx * 64 ~ 19 ms on the fast node) dominates the
# per-row work (t_row * 16 ~ 1.6 ms per slab) and the fixed overhead.
OCCUPANCIES = [0.0, 0.0, 0.5, 0.5]
CLUSTER_CM = CostModel(t_fixed=2e-3, t_row=1e-4, t_ctx=3e-4,
                       link_bw=50e9, link_latency=20e-6)
M_BASE_LAT, M_WARMUP_LAT = 100, 4
# every plan runs under the "ring" boundary policy (stale_async verdicts +
# per-hop staged K/V) with one corrective refresh every REFRESH boundaries
REFRESH = 8


def modeled_latency(m_base: int, m_warmup: int):
    cfg = get_config("sdxl-dit")
    base = StadiConfig.from_occupancies(
        OCCUPANCIES, m_base=m_base, m_warmup=m_warmup, backend="simulate",
        cost_model=CLUSTER_CM, exchange="ring", exchange_refresh=REFRESH)
    runs = {
        "uniform_pp": dataclasses.replace(base, planner="uniform"),
        "stadi_pp": dataclasses.replace(base, planner="stadi"),
        "stadi_seq_s2": dataclasses.replace(base, planner="stadi_seq",
                                            seq_shards=2),
        "stadi_seq_auto": dataclasses.replace(base, planner="stadi_seq",
                                              seq_shards=0),
    }
    out = {}
    for name, config in runs.items():
        pipe = StadiPipeline(cfg, None, None, config)
        res = pipe.generate()
        seq = res.plan.seq
        out[name] = {"latency_s": res.latency_s,
                     "patches": res.plan.patches,
                     "seq_heads": list(seq.heads) if seq else None,
                     "seq_segments": list(seq.segments) if seq else None}
    for name in runs:
        out[name]["reduction_vs_patch_pct"] = (
            (1.0 - out[name]["latency_s"] / out["stadi_pp"]["latency_s"])
            * 100.0)
    return out


def quality(m_base: int, m_warmup: int):
    """Bitwise shard invariance + ring-staleness PSNR drift, real numerics."""
    from repro.models.diffusion import dit
    cfg = get_config("tiny-dit").reduced()
    params = dit.nondegenerate_params(
        dit.init_params(jax.random.PRNGKey(0), cfg))
    sched = sampler_lib.linear_schedule(T=100)
    B = 2
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (B, cfg.latent_size, cfg.latent_size,
                             cfg.channels))
    cond = jnp.arange(B, dtype=jnp.int32) % cfg.n_classes
    origin = np.asarray(pp.run_origin(params, cfg, sched, x_T, cond, m_base))
    base = StadiConfig.from_occupancies([0.0, 0.2, 0.4, 0.5], m_base=m_base,
                                        m_warmup=m_warmup,
                                        exchange="ring", exchange_refresh=4)
    sync = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(base, exchange="sync")).generate(
            x_T, cond).image)
    s1 = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(base, seq_shards=1)).generate(x_T, cond).image)
    s2 = np.asarray(StadiPipeline(
        cfg, params, sched,
        dataclasses.replace(base, seq_shards=2)).generate(x_T, cond).image)
    out = {
        "s2_bitwise_vs_s1": bool(np.array_equal(s2, s1)),
        "sync": {"psnr_vs_origin_db": common.psnr(sync, origin)},
        "ring_s2": {"psnr_vs_origin_db": common.psnr(s2, origin)},
    }
    out["ring_s2"]["psnr_drift_vs_sync_db"] = (
        out["sync"]["psnr_vs_origin_db"]
        - out["ring_s2"]["psnr_vs_origin_db"])
    return out


def run(emit: bool = True):
    smoke = common.smoke()
    lat = modeled_latency(m_base=20 if smoke else M_BASE_LAT,
                          m_warmup=2 if smoke else M_WARMUP_LAT)
    qual = quality(m_base=8 if smoke else 16, m_warmup=2 if smoke else 4)
    if emit:
        for name, d in lat.items():
            common.emit(f"seqpar/{name}/latency", d["latency_s"] * 1e6,
                        f"reduction={d['reduction_vs_patch_pct']:.1f}% "
                        f"heads={d['seq_heads']}")
        drift_db = qual["ring_s2"]["psnr_drift_vs_sync_db"]
        common.emit("seqpar/ring_s2/psnr",
                    qual["ring_s2"]["psnr_vs_origin_db"],
                    f"drift={drift_db:+.2f}dB")
    payload = {
        "cluster": {"occupancies": OCCUPANCIES,
                    "cost_model": dataclasses.asdict(CLUSTER_CM)},
        "latency_arch": "sdxl-dit", "quality_arch": "tiny-dit(reduced)",
        "latency": lat, "quality": qual,
    }
    common.write_json("seqpar.json", payload)
    return payload


def main():
    res = run()
    lat, qual = res["latency"], res["quality"]
    red = lat["stadi_seq_auto"]["reduction_vs_patch_pct"]
    print(f"# stadi_seq(auto) modeled reduction vs pure patch parallelism: "
          f"{red:.1f}% (acceptance: >= 20%) — picked "
          f"heads={lat['stadi_seq_auto']['seq_heads']} "
          f"segments={lat['stadi_seq_auto']['seq_segments']}")
    print(f"# pinned S=2 reduction: "
          f"{lat['stadi_seq_s2']['reduction_vs_patch_pct']:.1f}% | uniform "
          f"patch baseline: "
          f"{lat['uniform_pp']['reduction_vs_patch_pct']:.1f}%")
    drift = qual["ring_s2"]["psnr_drift_vs_sync_db"]
    print(f"# ring policy S=2: PSNR "
          f"{qual['ring_s2']['psnr_vs_origin_db']:.2f} dB "
          f"(drift {drift:+.2f} dB vs synchronous; bar < 1 dB)")
    assert qual["s2_bitwise_vs_s1"], \
        "emulated reference must be shard-count invariant (bitwise)"
    assert red >= 20.0, (red, lat)
    assert drift < 1.0, (drift, qual)


if __name__ == "__main__":
    main()
