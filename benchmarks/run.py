"""Benchmark registry — one module per paper table/figure (DESIGN.md §8).

  PYTHONPATH=src python -m benchmarks.run [--smoke] [names...]

Prints ``name,us_per_call,derived`` CSV rows (also written to
results/bench.csv). ``--smoke`` exports STADI_BENCH_SMOKE=1 so benches run
shrunk workloads (the CI bench-smoke job)."""
from __future__ import annotations

import os
import sys
import time
import traceback

from benchmarks import common

REGISTRY = [
    ("kernels", "benchmarks.bench_kernels", "kernel micro vs oracles"),
    ("latency", "benchmarks.bench_latency", "paper Fig. 8"),
    ("ablation", "benchmarks.bench_ablation", "paper Table III"),
    ("patch_ratio", "benchmarks.bench_patch_ratio", "paper Fig. 9"),
    ("quality", "benchmarks.bench_quality", "paper Table II"),
    ("redundancy", "benchmarks.bench_redundancy", "paper Thm. 1/2"),
    ("beyond", "benchmarks.bench_beyond", "beyond-paper: tiers + reprofiling"),
    ("exchange", "benchmarks.bench_exchange", "boundary-exchange modes, DESIGN §10"),
    ("pipefuse", "benchmarks.bench_pipefuse", "displaced patch pipeline, DESIGN §11"),
    ("guidance", "benchmarks.bench_guidance", "CFG guidance placement, DESIGN §12"),
    ("seqpar", "benchmarks.bench_seqpar", "sequence-parallel attention, DESIGN §13"),
    ("video", "benchmarks.bench_video", "multi-frame diffusion, DESIGN §16"),
    ("textcond", "benchmarks.bench_textcond", "prompt conditioning, DESIGN §17"),
    ("roofline", "benchmarks.bench_roofline", "deliverable g"),
    ("serving", "benchmarks.bench_serving", "continuous batching, DESIGN §9"),
    ("load", "benchmarks.bench_load", "load generator + plan cache, DESIGN §14"),
]


def main() -> None:
    argv = sys.argv[1:]
    if "--smoke" in argv:
        argv = [a for a in argv if a != "--smoke"]
        os.environ["STADI_BENCH_SMOKE"] = "1"
    want = set(argv)
    failures = []
    for name, module, what in REGISTRY:
        if want and name not in want:
            continue
        print(f"## {name}  ({what})", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
            print(f"## {name} done in {time.time()-t0:.1f}s\n", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    common.flush_csv()
    if failures:
        print(f"FAILED benches: {failures}")
        raise SystemExit(1)
    print("all benchmarks OK")


if __name__ == "__main__":
    main()
