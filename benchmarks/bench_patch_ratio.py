"""Paper Fig. 9: latency vs patch-size ratio under several occupancy
settings; dashed line = pure patch parallelism, triangle = the ratio STADI's
Eq. 5 actually selects. Demonstrates (a) the latency bowl over the ratio and
(b) that the fixed-overhead term makes extreme ratios suboptimal (the paper's
observed nonlinearity).

The sweep replays hand-forced allocations through the simulator; the
"selected" point comes from the pipeline's ``"spatial"`` planner (SA-only).

In ``--smoke`` mode (CI bench-smoke) the cost model is a SEEDED
deterministic profile instead of a live single-step calibration: wall-clock
calibration noise on shared CI runners occasionally pushed Eq. 5's pick
past the 25% near-optimality tolerance at [0,60] (the flake CHANGES.md PR 3
recorded), and a latency-shape assertion needs a reproducible latency
model, not a reproducible machine.
"""
from __future__ import annotations

from benchmarks import common
from benchmarks.bench_latency import M_BASE, M_WARMUP
from repro.core import simulate as sim
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.core.simulate import build_trace

# deterministic smoke profile: a plausible CPU-host step-cost shape (fixed
# overhead ~ 1 ms/step, ~1 ms per token row) with none of the run-to-run
# noise live calibration has on shared runners (observed t_fixed varying
# 1e-6..4e-3 across back-to-back calibrations of the same model)
SMOKE_CM = sim.CostModel(t_fixed=1e-3, t_row=1e-3)


def run(emit=True):
    cfg, params, sched = common.load_tiny_dit()
    cm = SMOKE_CM if common.smoke() else common.calibrate_cost_model(cfg,
                                                                     params)
    P = cfg.tokens_per_side
    out = {}
    for occ in ([0.0, 0.2], [0.0, 0.4], [0.0, 0.6]):
        config = StadiConfig.from_occupancies(
            occ, m_base=M_BASE, m_warmup=M_WARMUP, planner="spatial",
            backend="simulate", cost_model=cm)
        pipe = StadiPipeline(cfg, params, sched, config)
        plan = pipe.plan()                             # SA-only (uniform steps)
        curve = {}
        for p0 in range(1, P):                         # hand-forced ratios
            t = sim.simulate_trace(build_trace(plan.temporal, [p0, P - p0], cfg),
                                   config.speeds, cm)
            curve[p0] = t
        best = min(curve, key=curve.get)
        sel = plan.patches[0]                          # Eq. 5's pick
        pp = curve[P // 2]
        key = f"[{int(occ[0]*100)},{int(occ[1]*100)}]"
        out[key] = (curve, best, sel, pp)
        if emit:
            common.emit(f"patch_ratio/{key}/pp_uniform", pp * 1e6, f"{pp:.2f}s")
            common.emit(f"patch_ratio/{key}/best", curve[best] * 1e6,
                        f"ratio {best}:{P-best}")
            common.emit(f"patch_ratio/{key}/stadi_selected", curve[sel] * 1e6,
                        f"ratio {sel}:{P-sel} (within "
                        f"{(curve[sel]/curve[best]-1)*100:.1f}% of best)")
    return out


def main():
    res = run()
    for key, (curve, best, sel, pp) in res.items():
        # Eq.5's pick is near-optimal on the simulated bowl. Tolerance 25%:
        # the paper itself observes (Fig. 9 discussion) that "when the load
        # gap is too large, patch allocation based on effective speed may not
        # yield optimal results" because of the fixed per-step overhead — we
        # reproduce that effect at [0,60].
        assert curve[sel] <= curve[best] * 1.25, (key, sel, best)
        # the bowl exists: extreme allocations are worse than the best
        P = max(curve)
        assert curve[1] > curve[best] and curve[P] > curve[best]
    print("# patch-ratio bowl reproduced; Eq.5 pick within tolerance")


if __name__ == "__main__":
    main()
