"""Deliverable (g): per-(arch x shape) roofline table from the dry-run
artifacts (results/dryrun/*.json, single-pod mesh), with dominant bottleneck
and MODEL_FLOPS / HLO_FLOPs usefulness ratio. Writes the markdown table
consumed by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common

DRYRUN = os.path.join(common.RESULTS, "dryrun")


def load(mesh: str = "pod16x16"):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        d = json.load(open(f))
        if d.get("ok") and "roofline" in d:
            rows.append(d)
    return rows


def table(rows):
    lines = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
             "| useful (6ND/HLO) | bytes/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for d in rows:
        r = d["roofline"]
        mem = d["memory_analysis"]
        per_dev = (mem.get("argument_size_in_bytes", 0) or 0) + \
                  (mem.get("temp_size_in_bytes", 0) or 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} "
            f"| {r['memory_s']:.4g} | {r['collective_s']:.4g} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.3f} "
            f"| {per_dev/1e9:.1f}GB |")
    return "\n".join(lines)


def run(emit=True):
    rows = load()
    if emit:
        for d in rows:
            r = d["roofline"]
            tot = r["compute_s"] + r["memory_s"] + r["collective_s"]
            common.emit(f"roofline/{r['arch']}/{r['shape']}", tot * 1e6,
                        f"dom={r['dominant']} useful={r['useful_ratio']:.3f}")
    md = table(rows)
    out = os.path.join(common.RESULTS, "roofline_table.md")
    with open(out, "w") as f:
        f.write(md + "\n")
    return rows, md


def main():
    rows, md = run()
    assert len(rows) == 40, f"expected 40 single-pod baselines, got {len(rows)}"
    print(md)


if __name__ == "__main__":
    main()
