"""Continuous batching vs sequential diffusion serving (DESIGN.md §9).

Drains N concurrent tiny-dit requests through the
:class:`~repro.serving.diffusion_engine.DiffusionServingEngine` on an
emulated 2-tier heterogeneous cluster (occupancies [0, 0.55] -> temporal
ratios {1, 2}) and compares against the sequential baseline of one
``StadiPipeline.generate`` call per request:

  * wall-clock throughput (img/s) — continuous batching must win (one
    vmapped dispatch covers every in-flight request);
  * per-request results must be **bitwise identical** to the sequential
    path (asserted, request by request);
  * modeled cluster latency (calibrated cost model) + an offered-load sweep
    with per-request latency percentiles and SLO hit-rates.

Structured results go to ``results/serving.json`` (uploaded as a CI
artifact by the bench-smoke job); summary rows go to the shared CSV.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import sampler as sampler_lib
from repro.core.pipeline import StadiConfig, StadiPipeline
from repro.models.diffusion import dit
from repro.serving import DiffusionServingEngine

OCC = [0.0, 0.55]        # 2-tier cluster: speeds [1.0, 0.45] -> ratios (1, 2)
N_REQUESTS = 16
SLOTS = 8


def _requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    xs = [jax.random.normal(jax.random.PRNGKey(seed + 1 + i),
                            (1, cfg.latent_size, cfg.latent_size,
                             cfg.channels)) for i in range(n)]
    conds = [jnp.asarray([int(c)], jnp.int32)
             for c in rng.integers(0, cfg.n_classes, n)]
    return xs, conds


def _drain(pipe, xs, conds, slo_s=None):
    engine = DiffusionServingEngine(pipe, slots=SLOTS)
    t0 = time.perf_counter()
    reqs = [engine.submit(x, c, slo_s=slo_s) for x, c in zip(xs, conds)]
    engine.run_to_completion()
    return engine, reqs, time.perf_counter() - t0


def run(emit=True):
    smoke = common.smoke()
    m_base, m_warmup = (8, 2) if smoke else (16, 4)
    cfg = get_config("tiny-dit").reduced()
    params = dit.init_params(jax.random.PRNGKey(0), cfg)
    sched = sampler_lib.linear_schedule(T=1000)
    cm = common.calibrate_cost_model(cfg, params)
    config = StadiConfig.from_occupancies(OCC, m_base=m_base,
                                          m_warmup=m_warmup, cost_model=cm)
    pipe = StadiPipeline(cfg, params, sched, config)
    plan = pipe.plan()
    tiers = sorted({r for r in plan.temporal.ratios if r})
    assert tiers == [1, 2], f"expected a 2-tier cluster, got ratios {tiers}"

    xs, conds = _requests(cfg, N_REQUESTS)

    # warm both jit caches so the timed region measures steady-state serving
    pipe.generate(xs[0], conds[0])
    _drain(pipe, xs[:SLOTS], conds[:SLOTS])

    # -- sequential baseline: one generate() per request ------------------
    t0 = time.perf_counter()
    seq = [pipe.generate(x, c) for x, c in zip(xs, conds)]
    jax.block_until_ready(seq[-1].image)
    wall_seq = time.perf_counter() - t0

    # -- continuous batching ----------------------------------------------
    engine, reqs, wall_cb = _drain(pipe, xs, conds)
    for r, s in zip(reqs, seq):
        assert bool(jnp.all(r.image == s.image)), \
            f"request {r.uid} diverged from single-request generate()"

    thr_seq, thr_cb = N_REQUESTS / wall_seq, N_REQUESTS / wall_cb
    modeled_seq_s = sum(s.latency_s for s in seq)
    stats = engine.stats()
    comparison = {
        "n_requests": N_REQUESTS,
        "slots": SLOTS,
        "wall_seq_s": wall_seq,
        "wall_cb_s": wall_cb,
        "throughput_seq_rps": thr_seq,
        "throughput_cb_rps": thr_cb,
        "wall_speedup": thr_cb / thr_seq,
        "modeled_seq_makespan_s": modeled_seq_s,
        "modeled_cb_makespan_s": stats["modeled_makespan_s"],
        "modeled_speedup": modeled_seq_s / stats["modeled_makespan_s"],
        "bitwise_identical": True,               # asserted above
    }
    assert thr_cb > thr_seq, (
        f"continuous batching ({thr_cb:.2f} img/s) must beat sequential "
        f"({thr_seq:.2f} img/s)")

    # -- offered-load sweep: latency/SLO vs concurrency -------------------
    slo_s = 2.0 * modeled_seq_s / N_REQUESTS     # 2x a lone request's latency
    sweep = []
    for load in ([4, 16] if smoke else [4, 8, 16]):
        sxs, sconds = _requests(cfg, load, seed=100 + load)
        eng, _, wall = _drain(pipe, sxs, sconds, slo_s=slo_s)
        st = eng.stats()
        sweep.append({
            "offered_load": load,
            "wall_s": wall,
            "throughput_wall_rps": load / wall,
            "throughput_modeled_rps": st["throughput_modeled_rps"],
            "latency_mean_s": st["latency_mean_s"],
            "latency_p95_s": st["latency_p95_s"],
            "slo_s": slo_s,
            "slo_met_frac": st["slo_met_frac"],
        })

    payload = {
        "arch": cfg.arch_id,
        "occupancies": OCC,
        "m_base": m_base,
        "m_warmup": m_warmup,
        "plan_ratios": list(plan.temporal.ratios),
        "plan_patches": list(plan.patches),
        "cost_model": {"t_fixed": cm.t_fixed, "t_row": cm.t_row},
        "smoke": smoke,
        "comparison": comparison,
        "offered_load_sweep": sweep,
    }
    common.write_json("serving.json", payload)
    if emit:
        common.emit("serving/seq_wall", wall_seq / N_REQUESTS * 1e6,
                    f"{thr_seq:.2f} img/s")
        common.emit("serving/cb_wall", wall_cb / N_REQUESTS * 1e6,
                    f"{thr_cb:.2f} img/s speedup={thr_cb/thr_seq:.2f}x")
        common.emit("serving/cb_modeled",
                    stats["modeled_makespan_s"] / N_REQUESTS * 1e6,
                    f"modeled speedup={comparison['modeled_speedup']:.2f}x")
        for row in sweep:
            common.emit(f"serving/load{row['offered_load']}",
                        row["latency_mean_s"] * 1e6,
                        f"p95={row['latency_p95_s']*1e3:.1f}ms "
                        f"slo_met={row['slo_met_frac']}")
    return payload


def main():
    out = run()
    c = out["comparison"]
    print(f"# continuous batching: {c['throughput_cb_rps']:.2f} img/s wall "
          f"vs sequential {c['throughput_seq_rps']:.2f} img/s "
          f"({c['wall_speedup']:.2f}x), modeled {c['modeled_speedup']:.2f}x, "
          f"bitwise identical per request")


if __name__ == "__main__":
    main()
